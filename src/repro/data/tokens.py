"""Token batch pipeline: synthetic shards + modality stubs + input_specs.

`make_batch` returns REAL arrays (smoke tests / training on CPU);
`input_specs` returns jax.ShapeDtypeStruct stand-ins with identical
structure (multi-pod dry-run lowering, no allocation).

The pipeline is deterministic per (seed, step): a restarted job replays
or skips ahead without coordination — the data-side half of
checkpoint/restart fault tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


def _token_seq_len(cfg: ModelConfig, seq: int) -> int:
    """Text tokens after reserving room for modality stubs."""
    if cfg.patch_input:
        return seq - cfg.n_patches
    return seq


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
               step: int = 0) -> dict:
    """Synthetic training batch (deterministic in (seed, step))."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + step)
    st = _token_seq_len(cfg, seq)
    out = {}
    if cfg.family == "encdec":
        # seq applies to the SOURCE frames; target is seq//8 (min 32)
        tgt = max(seq // 8, 32)
        out["frames"] = rng.standard_normal(
            (batch, seq, cfg.frame_dim), np.float32)
        out["frame_len"] = np.full((), seq, np.int32)
        out["tokens"] = rng.integers(0, cfg.vocab, (batch, tgt),
                                     dtype=np.int32)
        out["labels"] = rng.integers(0, cfg.vocab, (batch, tgt),
                                     dtype=np.int32)
        out["mask"] = np.ones((batch, tgt), np.float32)
        return {k: jnp.asarray(v) for k, v in out.items()}
    out["tokens"] = rng.integers(0, cfg.vocab, (batch, st), dtype=np.int32)
    lab_len = seq if cfg.patch_input else st
    out["labels"] = rng.integers(0, cfg.vocab, (batch, lab_len),
                                 dtype=np.int32)
    mask = np.ones((batch, lab_len), np.float32)
    if cfg.patch_input:
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.patch_dim), np.float32)
        mask[:, :cfg.n_patches] = 0.0      # no loss on image positions
    out["mask"] = mask
    return {k: jnp.asarray(v) for k, v in out.items()}


def input_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct stand-ins mirroring make_batch (dry-run)."""
    sd = jax.ShapeDtypeStruct
    st = _token_seq_len(cfg, seq)
    if cfg.family == "encdec":
        tgt = max(seq // 8, 32)
        return {
            "frames": sd((batch, seq, cfg.frame_dim), jnp.float32),
            "frame_len": sd((), jnp.int32),
            "tokens": sd((batch, tgt), jnp.int32),
            "labels": sd((batch, tgt), jnp.int32),
            "mask": sd((batch, tgt), jnp.float32),
        }
    out = {
        "tokens": sd((batch, st), jnp.int32),
        "labels": sd((batch, seq if cfg.patch_input else st), jnp.int32),
        "mask": sd((batch, seq if cfg.patch_input else st), jnp.float32),
    }
    if cfg.patch_input:
        out["patches"] = sd((batch, cfg.n_patches, cfg.patch_dim),
                            jnp.float32)
    return out


class TokenPipeline:
    """Stateful iterator with skip-ahead (resume support)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = start_step

    def __next__(self):
        b = make_batch(self.cfg, self.batch, self.seq, self.seed,
                       self.step)
        self.step += 1
        return b

    def skip_to(self, step: int):
        self.step = step
