"""Synthetic spatial dataset generators (paper §5.1.1 stand-ins).

  uniform   ~ SYN  (Spider-style random points)
  gaussian  ~ CHI  (city crime: few dense clusters)
  taxi      ~ NYC  (street-grid-ish anisotropic clusters + arterials)

All generators are seeded and return float32 (x, y) in [0, 1]^2-ish space
so experiments are exactly reproducible.
"""
from __future__ import annotations

import numpy as np


def uniform(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2), dtype=np.float32)
    return pts[:, 0], pts[:, 1]


def gaussian(n: int, seed: int = 0, clusters: int = 12, spread: float = 0.04):
    rng = np.random.default_rng(seed)
    centers = rng.random((clusters, 2))
    weights = rng.dirichlet(np.ones(clusters) * 0.6)
    sizes = rng.multinomial(n, weights)
    xs, ys = [], []
    for c, s in zip(centers, sizes):
        p = rng.normal(c, spread, (s, 2))
        xs.append(p[:, 0])
        ys.append(p[:, 1])
    x = np.clip(np.concatenate(xs), 0, 1).astype(np.float32)
    y = np.clip(np.concatenate(ys), 0, 1).astype(np.float32)
    perm = rng.permutation(n)
    return x[perm], y[perm]


def taxi(n: int, seed: int = 0):
    """Anisotropic 'street grid' mixture: dense downtown + arterials."""
    rng = np.random.default_rng(seed)
    n_dt = n // 2
    n_art = n // 4
    n_bg = n - n_dt - n_art
    downtown = rng.normal([0.5, 0.55], [0.05, 0.09], (n_dt, 2))
    t = rng.random(n_art)
    art = np.stack([0.1 + 0.8 * t, 0.3 + 0.35 * t], axis=1)
    art += rng.normal(0, [0.01, 0.03], (n_art, 2))
    bg = rng.random((n_bg, 2))
    pts = np.concatenate([downtown, art, bg])
    pts = np.clip(pts, 0, 1).astype(np.float32)
    perm = rng.permutation(n)
    return pts[perm, 0], pts[perm, 1]


GENERATORS = {"uniform": uniform, "gaussian": gaussian, "taxi": taxi}


def make(kind: str, n: int, seed: int = 0):
    return GENERATORS[kind](n, seed)


def random_rects(n: int, sel: float, bounds, seed: int = 0, centers=None):
    """Query rects with given selectivity (area fraction). If ``centers``
    (x, y arrays) given, rect centers follow the data distribution
    (the paper's 'skewed' queries); else uniform."""
    rng = np.random.default_rng(seed)
    xl, yl, xh, yh = bounds
    w = (xh - xl) * np.sqrt(sel)
    h = (yh - yl) * np.sqrt(sel)
    if centers is None:
        cx = rng.uniform(xl, xh, n)
        cy = rng.uniform(yl, yh, n)
    else:
        ix = rng.integers(0, len(centers[0]), n)
        cx, cy = np.asarray(centers[0])[ix], np.asarray(centers[1])[ix]
    rects = np.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2],
                     axis=1).astype(np.float32)
    return rects


def random_polygons(n: int, bounds, seed: int = 0, max_edges: int = 12,
                    radius: float = 0.03):
    """Star-convex random polygons (possibly concave) + edge counts."""
    rng = np.random.default_rng(seed)
    xl, yl, xh, yh = bounds
    polys = np.zeros((n, max_edges, 2), np.float32)
    n_edges = np.zeros((n,), np.int32)
    for i in range(n):
        e = int(rng.integers(3, max_edges + 1))
        cx = rng.uniform(xl + radius, xh - radius)
        cy = rng.uniform(yl + radius, yh - radius)
        ang = np.sort(rng.uniform(0, 2 * np.pi, e))
        rad = rng.uniform(0.3 * radius, radius, e)
        polys[i, :e, 0] = cx + rad * np.cos(ang)
        polys[i, :e, 1] = cy + rad * np.sin(ang)
        n_edges[i] = e
    return polys, n_edges
