"""Pallas TPU kernel: morton (Z-order) bit-interleave of quantized coords.

Pure VPU elementwise op on uint32 lanes. Points are reshaped to
(rows, LANE) and blocked (BLOCK_ROWS, LANE) in VMEM: 8x128 matches the
TPU vreg tile for 32-bit lanes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 8


def _spread(v):
    v = (v | (v << jnp.uint32(8))) & jnp.uint32(0x00FF00FF)
    v = (v | (v << jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << jnp.uint32(2))) & jnp.uint32(0x33333333)
    v = (v | (v << jnp.uint32(1))) & jnp.uint32(0x55555555)
    return v


def _morton_kernel(qx_ref, qy_ref, out_ref):
    x = qx_ref[...]
    y = qy_ref[...]
    out_ref[...] = _spread(x) | (_spread(y) << jnp.uint32(1))


@partial(jax.jit, static_argnames=("interpret",))
def morton_encode_2d(qx, qy, *, interpret: bool):
    """qx, qy: (rows, LANE) uint32 quantized coords -> morton keys."""
    rows, lane = qx.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _morton_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint32),
        interpret=interpret,
    )(qx, qy)
