"""Pure-jnp oracles for every Pallas kernel (correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.core import queries as CQ


def morton_encode(qx, qy):
    """(..., ) uint32 quantized coords -> morton keys."""
    return CK.morton_encode(qx, qy)


def spline_search(queries, knot_keys, knot_pos, radix_table, keys_f,
                  kmin, scale, n_knots, count, *, probe, radix_bits):
    """Exact lower-bound positions (first idx with key >= q)."""
    part = {
        "keys_f": keys_f, "knot_keys": knot_keys, "knot_pos": knot_pos,
        "n_knots": jnp.asarray(n_knots, jnp.int32),
        "radix_table": radix_table,
        "radix_kmin": jnp.asarray(kmin, jnp.float32),
        "radix_scale": jnp.asarray(scale, jnp.float32),
        "count": jnp.asarray(count, jnp.int32),
    }
    return CQ.learned_lower_bound(part, queries, radix_bits=radix_bits,
                                  probe=probe)


def range_count(rects, se, count, x, y):
    """(Q,) exact in-rect counts within [s, e) position intervals."""
    n = x.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    s = se[:, 0:1].astype(jnp.int32)
    e = se[:, 1:2].astype(jnp.int32)
    m = ((pos[None, :] >= s) & (pos[None, :] < e) &
         (pos[None, :] < count) &
         (x[None, :] >= rects[:, 0:1]) & (x[None, :] <= rects[:, 2:3]) &
         (y[None, :] >= rects[:, 1:2]) & (y[None, :] <= rects[:, 3:4]))
    return jnp.sum(m.astype(jnp.int32), axis=1)


def circle_count(rects, se, circ, count, x, y):
    """(Q,) exact in-circle counts within [s, e) position intervals
    (MBR filter + distance refine — the fused kernel's oracle)."""
    n = x.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    s = se[:, 0:1].astype(jnp.int32)
    e = se[:, 1:2].astype(jnp.int32)
    dx = x[None, :] - circ[:, 0:1]
    dy = y[None, :] - circ[:, 1:2]
    m = ((pos[None, :] >= s) & (pos[None, :] < e) &
         (pos[None, :] < count) &
         (x[None, :] >= rects[:, 0:1]) & (x[None, :] <= rects[:, 2:3]) &
         (y[None, :] >= rects[:, 1:2]) & (y[None, :] <= rects[:, 3:4]) &
         (dx * dx + dy * dy <= circ[:, 2:3] ** 2))
    return jnp.sum(m.astype(jnp.int32), axis=1)


def point_probe(qkf, qx, qy, wk, wx, wy, *, probe):
    """(Q,) exact-match counts in gathered (Q, W >= probe) windows."""
    lane = jnp.arange(wk.shape[1], dtype=jnp.int32)
    m = ((lane[None, :] < probe) &
         (wk == qkf[:, None]) &
         (wx == qx[:, None]) & (wy == qy[:, None]))
    return jnp.sum(m.astype(jnp.int32), axis=1)


def knn_topk(qxy, count, px, py, *, k):
    """(neg_d2 (Q,k), idx (Q,k)) via lax.top_k on negated distances.

    top_k's lowest-index tie-break matches both the stable argsort this
    replaces and the kernel's max-then-first-hit selection, at O(N*k)
    instead of O(N log N)."""
    d2 = ((px[None, :] - qxy[:, 0:1]) ** 2 +
          (py[None, :] - qxy[:, 1:2]) ** 2)
    pos = jnp.arange(px.shape[0], dtype=jnp.int32)
    d2 = jnp.where(pos[None, :] < count, d2, 3.0e38)
    negv, order = jax.lax.top_k(-d2, k)
    best = -negv
    idx = jnp.where(best < 3.0e38, order.astype(jnp.int32), -1)
    return -jnp.where(best < 3.0e38, best, 3.0e38), idx


def point_in_polygon(poly, n_edges, x, y):
    """(N,) int32 inside flags (ray casting)."""
    return CQ.point_in_polygon(x, y, poly,
                               jnp.asarray(n_edges, jnp.int32)
                               ).astype(jnp.int32)
