"""Public jit'd wrappers around the Pallas kernels.

Each op pads inputs to kernel block multiples, dispatches interpret mode
automatically on non-TPU backends, and strips padding from outputs, so
callers (engine / benchmarks / tests) see clean shapes.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels import circle_filter as _cf
from repro.kernels import knn_topk as _knn
from repro.kernels import morton as _morton
from repro.kernels import point_in_polygon as _pip
from repro.kernels import point_probe as _pp
from repro.kernels import range_filter as _rf
from repro.kernels import spline_search as _ss
from repro.kernels.common import interpret_default, pad_to, cdiv


def _interp(flag: Optional[bool]) -> bool:
    return interpret_default() if flag is None else flag


def morton_encode(qx, qy, interpret: Optional[bool] = None):
    """(N,) uint32 quantized coords -> (N,) uint32 morton keys."""
    n = qx.shape[0]
    row = _morton.LANE
    rows = cdiv(n, row * _morton.BLOCK_ROWS) * _morton.BLOCK_ROWS
    qx2 = pad_to(qx, rows * row, 0, 0).reshape(rows, row)
    qy2 = pad_to(qy, rows * row, 0, 0).reshape(rows, row)
    out = _morton.morton_encode_2d(qx2, qy2, interpret=_interp(interpret))
    return out.reshape(-1)[:n]


def spline_search(queries, knot_keys, knot_pos, radix_table, keys_f,
                  kmin, scale, n_knots, count, *, probe: int,
                  radix_bits: int, interpret: Optional[bool] = None):
    """Exact learned lower-bound positions for (Q,) query keys."""
    nq = queries.shape[0]
    qpad = cdiv(nq, _ss.QBLOCK) * _ss.QBLOCK
    q = pad_to(jnp.asarray(queries, jnp.float32), qpad, 0, 0.0)
    scal = jnp.zeros((1, 8), jnp.float32)
    scal = scal.at[0, 0].set(kmin).at[0, 1].set(scale)
    scal = scal.at[0, 2].set(jnp.asarray(n_knots, jnp.float32))
    scal = scal.at[0, 3].set(jnp.asarray(count, jnp.float32))
    pos = _ss.spline_search(q, knot_keys, knot_pos, radix_table, keys_f,
                            scal, probe=probe, radix_bits=radix_bits,
                            interpret=_interp(interpret))
    return pos[:nq]


def range_count(rects, se, count, x, y, interpret: Optional[bool] = None):
    """(Q,) in-rect counts within learned [s, e) intervals."""
    nq = rects.shape[0]
    n = x.shape[0]
    qpad = cdiv(nq, _rf.QB) * _rf.QB
    npad = cdiv(n, _rf.NB) * _rf.NB
    rects_p = pad_to(jnp.asarray(rects, jnp.float32), qpad, 0, 0.0)
    se_p = pad_to(jnp.asarray(se, jnp.float32), qpad, 0, 0.0)
    x_p = pad_to(jnp.asarray(x, jnp.float32), npad, 0, 3e38)
    y_p = pad_to(jnp.asarray(y, jnp.float32), npad, 0, 3e38)
    cnt = jnp.asarray([[np.float32(0)]], jnp.float32).at[0, 0].set(
        jnp.asarray(count, jnp.float32))
    out = _rf.range_count(rects_p, se_p, cnt, x_p, y_p,
                          interpret=_interp(interpret))
    return out[:nq]


def circle_count(rects, se, circ, count, x, y,
                 interpret: Optional[bool] = None):
    """(Q,) in-circle counts within learned [s, e) intervals (fused
    MBR filter + distance refine in one kernel pass)."""
    nq = rects.shape[0]
    n = x.shape[0]
    qpad = cdiv(nq, _cf.QB) * _cf.QB
    npad = cdiv(n, _cf.NB) * _cf.NB
    rects_p = pad_to(jnp.asarray(rects, jnp.float32), qpad, 0, 0.0)
    se_p = pad_to(jnp.asarray(se, jnp.float32), qpad, 0, 0.0)
    circ_p = pad_to(jnp.asarray(circ, jnp.float32), qpad, 0, 0.0)
    x_p = pad_to(jnp.asarray(x, jnp.float32), npad, 0, 3e38)
    y_p = pad_to(jnp.asarray(y, jnp.float32), npad, 0, 3e38)
    cnt = jnp.zeros((1, 1), jnp.float32).at[0, 0].set(
        jnp.asarray(count, jnp.float32))
    out = _cf.circle_count(rects_p, se_p, circ_p, cnt, x_p, y_p,
                           interpret=_interp(interpret))
    return out[:nq]


def point_probe(qkf, qx, qy, wk, wx, wy, *, probe: int,
                interpret: Optional[bool] = None):
    """(Q,) exact-match counts in each query's gathered probe window
    (wk/wx/wy: (Q, W >= probe) f32; lanes >= probe are ignored)."""
    nq = qkf.shape[0]
    w = wk.shape[1]
    qpad = cdiv(nq, _pp.QB) * _pp.QB
    wpad = cdiv(w, 128) * 128
    q3 = jnp.stack([jnp.asarray(qkf, jnp.float32),
                    jnp.asarray(qx, jnp.float32),
                    jnp.asarray(qy, jnp.float32),
                    jnp.zeros(nq, jnp.float32)], axis=1)
    q3 = pad_to(q3, qpad, 0, 0.0)
    # window padding uses -3e38 (query pad rows are 0.0, so padding can
    # never fabricate a match before the [:nq] slice anyway)
    wk_p = pad_to(pad_to(jnp.asarray(wk, jnp.float32), wpad, 1, -3e38),
                  qpad, 0, -3e38)
    wx_p = pad_to(pad_to(jnp.asarray(wx, jnp.float32), wpad, 1, -3e38),
                  qpad, 0, -3e38)
    wy_p = pad_to(pad_to(jnp.asarray(wy, jnp.float32), wpad, 1, -3e38),
                  qpad, 0, -3e38)
    out = _pp.point_probe(q3, wk_p, wx_p, wy_p, probe=probe,
                          interpret=_interp(interpret))
    return out[:nq]


def knn_topk(qxy, count, px, py, *, k: int,
             interpret: Optional[bool] = None):
    """Per-query top-k (neg_d2, idx) over one partition's points."""
    nq = qxy.shape[0]
    n = px.shape[0]
    qpad = cdiv(nq, _knn.QB) * _knn.QB
    npad = cdiv(n, _knn.NB) * _knn.NB
    qxy_p = pad_to(jnp.asarray(qxy, jnp.float32), qpad, 0, 0.0)
    px_p = pad_to(jnp.asarray(px, jnp.float32), npad, 0, 3e38)
    py_p = pad_to(jnp.asarray(py, jnp.float32), npad, 0, 3e38)
    cnt = jnp.zeros((1, 1), jnp.float32).at[0, 0].set(
        jnp.asarray(count, jnp.float32))
    negd, idx = _knn.knn_topk(qxy_p, cnt, px_p, py_p, k=k,
                              interpret=_interp(interpret))
    return negd[:nq], idx[:nq]


def point_in_polygon(poly, n_edges, x, y, interpret: Optional[bool] = None):
    """(N,) int32 ray-casting containment flags."""
    n = x.shape[0]
    npad = cdiv(n, _pip.NB) * _pip.NB
    x_p = pad_to(jnp.asarray(x, jnp.float32), npad, 0, 3e38)
    y_p = pad_to(jnp.asarray(y, jnp.float32), npad, 0, 3e38)
    ne = jnp.zeros((1, 1), jnp.float32).at[0, 0].set(
        jnp.asarray(n_edges, jnp.float32))
    out = _pip.point_in_polygon(jnp.asarray(poly, jnp.float32), ne,
                                x_p, y_p, interpret=_interp(interpret))
    return out[:n]
