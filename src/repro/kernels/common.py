"""Shared Pallas kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def interpret_default() -> bool:
    """Run kernels in interpret mode unless on a real TPU."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(a, n: int, axis: int, fill):
    """Pad axis up to length n with fill."""
    cur = a.shape[axis]
    if cur == n:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, n - cur)
    return jnp.pad(a, widths, constant_values=fill)


def pad_pow2_rows(a, row: int, fill):
    """Reshape (N,) -> (rows, row) padding with fill (for 2-D TPU blocks)."""
    n = a.shape[0]
    rows = cdiv(n, row)
    a = pad_to(a, rows * row, 0, fill)
    return a.reshape(rows, row), n


def iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def scalars_f32(*vals):
    """(1, len(vals)) float32 scalar carrier (SMEM-friendly)."""
    return jnp.asarray([list(np.float32(v) for v in vals)], jnp.float32)
