"""Pallas TPU kernel: fused circle refine — masked range filter AND
distance test in ONE pass.

Grid (query blocks x point blocks), the same accumulation shape as
range_filter: each step evaluates a (QB, NB) containment mask — learned
[s, e) interval AND the circle's MBR AND the squared-distance test —
and accumulates per-query counts into the output block resident in
VMEM across the inner (point) grid axis. Fusing the distance test into
the filter pass removes the separate refine sweep (and its second read
of the x/y planes) that the reference backend performs; the distance
math is the identical f32 expression, so interpret-mode counts are
bitwise the reference's.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import iota2

QB = 128
NB = 512


def _kernel(rect_ref, se_ref, circ_ref, cnt_ref, x_ref, y_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = j * NB + iota2((1, NB), 1)                    # global positions
    count = cnt_ref[0, 0].astype(jnp.int32)
    s = se_ref[:, 0:1].astype(jnp.int32)                # (QB, 1)
    e = se_ref[:, 1:2].astype(jnp.int32)
    x = x_ref[...]                                      # (1, NB)
    y = y_ref[...]
    dx = x - circ_ref[:, 0:1]                           # (QB, NB)
    dy = y - circ_ref[:, 1:2]
    r = circ_ref[:, 2:3]
    m = ((pos >= s) & (pos < e) & (pos < count) &
         (x >= rect_ref[:, 0:1]) & (x <= rect_ref[:, 2:3]) &
         (y >= rect_ref[:, 1:2]) & (y <= rect_ref[:, 3:4]) &
         (dx * dx + dy * dy <= r * r))
    out_ref[...] += jnp.sum(m.astype(jnp.int32), axis=1, keepdims=True)


@partial(jax.jit, static_argnames=("interpret",))
def circle_count(rects, se, circ, cnt_scalar, x, y, *, interpret: bool):
    """In-circle counts within learned intervals, one partition.

    rects: (Q, 4) f32 circle MBRs ; se: (Q, 2) f32 learned [s, e)
    circ: (Q, 3) f32 [cx, cy, r] ; cnt_scalar: (1, 1) f32 valid-count
    x, y: (N,) f32. Returns (Q,) int32.
    """
    nq = rects.shape[0]
    n = x.shape[0]
    assert nq % QB == 0 and n % NB == 0
    grid = (nq // QB, n // NB)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QB, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((QB, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((QB, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, NB), lambda i, j: (0, j)),
            pl.BlockSpec((1, NB), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((QB, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        interpret=interpret,
    )(rects, se, circ, cnt_scalar, x.reshape(1, -1), y.reshape(1, -1))
    return out.reshape(-1)
