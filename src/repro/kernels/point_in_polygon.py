"""Pallas TPU kernel: ray-casting point-in-polygon (join refine phase).

Grid over point blocks; the (small, broadcast) polygon vertex list stays
whole in VMEM — the paper's broadcast-join structure. A fori_loop walks
edges; each edge updates the crossing parity of the whole (1, NB) lane
vector (VPU). Cost: E vector ops per block, E <= a few dozen.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NB = 512


def _kernel(scal_ref, poly_ref, x_ref, y_ref, out_ref):
    n_edges = scal_ref[0, 0].astype(jnp.int32)
    e_max = poly_ref.shape[0]
    px = x_ref[...]
    py = y_ref[...]

    def body(i, parity):
        p1 = pl.load(poly_ref, (pl.ds(i, 1), slice(None)))      # (1, 2)
        nxt = jnp.where(i + 1 >= n_edges, 0, i + 1)
        p2 = pl.load(poly_ref, (pl.ds(nxt, 1), slice(None)))
        x1, y1 = p1[0, 0], p1[0, 1]
        x2, y2 = p2[0, 0], p2[0, 1]
        cond = (y1 > py) != (y2 > py)
        t = (py - y1) / jnp.where(y2 == y1, 1e-30, y2 - y1)
        xin = x1 + t * (x2 - x1)
        crosses = cond & (px < xin) & (i < n_edges)
        return parity ^ crosses

    parity = jax.lax.fori_loop(
        0, e_max, body, jnp.zeros(px.shape, dtype=jnp.bool_))
    out_ref[...] = parity.astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def point_in_polygon(poly, n_edges_scalar, x, y, *, interpret: bool):
    """poly: (E, 2) f32 ; n_edges_scalar: (1, 1) f32 ; x, y: (N,) f32.

    Returns (N,) int32 inside flags.
    """
    n = x.shape[0]
    e = poly.shape[0]
    assert n % NB == 0
    grid = (n // NB,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((e, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, NB), lambda i: (0, i)),
            pl.BlockSpec((1, NB), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, NB), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(n_edges_scalar, poly, x.reshape(1, -1), y.reshape(1, -1))
    return out.reshape(-1)
