"""Pallas TPU kernel: batched kNN distance + streaming top-k merge.

Grid (query blocks x point blocks). Each step computes the (QB, NB)
squared-distance tile on the VPU and merges it into the running per-query
top-k held in the output blocks (resident in VMEM across the point axis).

Merge strategy: k rounds of (max, mask) selection over the concatenated
candidate row — k is small (paper: k <= 100, default 10), so k*(NB+k)
compares per tile beat a full sort. Index tracking uses the
iota-equality-select idiom (no gather needed on the lane axis).

Note on the MXU: for 2-D spatial coords the classic
||q-p||^2 = ||q||^2 + ||p||^2 - 2 q.p matmul trick degenerates to a
(QB x 2 x NB) contraction — too thin to feed the 128x128 systolic array,
so the VPU broadcast form is used; the matmul form wins only for
high-dimensional points (documented in DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import iota2

QB = 128
NB = 512
NEG = -3.0e38  # python float: avoids captured-const tracing in the kernel


def _kernel(q_ref, cnt_ref, px_ref, py_ref, outv_ref, outi_ref, *, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        outv_ref[...] = jnp.full_like(outv_ref, NEG)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    count = cnt_ref[0, 0].astype(jnp.int32)
    pos = j * NB + iota2((1, NB), 1)
    qx = q_ref[:, 0:1]
    qy = q_ref[:, 1:2]
    dx = px_ref[...] - qx                               # (QB, NB)
    dy = py_ref[...] - qy
    negd = jnp.where(pos < count, -(dx * dx + dy * dy), NEG)

    cand_v = jnp.concatenate([outv_ref[...], negd], axis=1)
    cand_i = jnp.concatenate(
        [outi_ref[...], jnp.broadcast_to(pos, negd.shape)], axis=1)
    width = cand_v.shape[1]
    lane = iota2((1, width), 1)

    best_v = []
    best_i = []
    for _ in range(k):                                   # static unroll
        m = jnp.max(cand_v, axis=1, keepdims=True)       # (QB, 1)
        hit = (cand_v == m) & (jnp.cumsum(
            (cand_v == m).astype(jnp.int32), axis=1) == 1)
        sel_i = jnp.sum(jnp.where(hit, cand_i, 0), axis=1, keepdims=True)
        best_v.append(m)
        best_i.append(sel_i)
        cand_v = jnp.where(hit, NEG, cand_v)
        del lane
        lane = None
    outv_ref[...] = jnp.concatenate(best_v, axis=1)
    outi_ref[...] = jnp.concatenate(best_i, axis=1)


@partial(jax.jit, static_argnames=("k", "interpret"))
def knn_topk(qxy, cnt_scalar, px, py, *, k: int, interpret: bool):
    """Top-k nearest points per query on ONE partition.

    qxy: (Q, 2) f32 ; cnt_scalar: (1, 1) f32 ; px, py: (N,) f32
    Returns (neg_d2 (Q, k) f32, idx (Q, k) int32) — idx are positions in
    the partition row (map through vid outside).
    """
    nq = qxy.shape[0]
    n = px.shape[0]
    assert nq % QB == 0 and n % NB == 0
    grid = (nq // QB, n // NB)
    outv, outi = pl.pallas_call(
        partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((QB, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, NB), lambda i, j: (0, j)),
            pl.BlockSpec((1, NB), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((QB, k), lambda i, j: (i, 0)),
            pl.BlockSpec((QB, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(qxy, cnt_scalar, px.reshape(1, -1), py.reshape(1, -1))
    return outv, outi
