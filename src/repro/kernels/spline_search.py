"""Pallas TPU kernel: learned lower-bound search (paper Fig. 3).

Per query key: radix-table bucket -> knot window [T[j], T[j+1]] ->
branchless masked compare-count segment locate -> linear interpolation ->
eps-bounded probe window compare-count over the sorted key array.

VMEM layout (per grid step):
  queries     (1, QB)         blocked over the grid
  knot keys   (1, M)          whole array resident (M <= a few K)
  knot pos    (1, M)          whole array resident
  radix table (1, R)          whole array resident (R = 2^b + 2)
  keys        (1, N)          whole sorted key array resident; partitions
                              are sized at build so N*4B fits VMEM — the
                              HBM->VMEM copy is amortized over the whole
                              query batch on that partition.
Scalars (kmin, scale, n_knots, count) ride in a (1, 8) f32 block.

Queries within a block are processed by a fori_loop (scalar dynamic
slices are TPU-supported; the vector work per query is the masked
compare-count over M knots + the probe window).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import iota2

QBLOCK = 128


def _kernel(scal_ref, q_ref, kk_ref, kp_ref, rt_ref, keys_ref, out_ref, *,
            probe: int, radix_bits: int):
    kmin = scal_ref[0, 0]
    scale = scal_ref[0, 1]
    n_knots = scal_ref[0, 2].astype(jnp.int32)
    count = scal_ref[0, 3].astype(jnp.int32)
    m_pad = kk_ref.shape[1]
    n_pad = keys_ref.shape[1]
    kidx = iota2((1, m_pad), 1)

    def one(i, _):
        q = q_ref[0, i]
        # --- radix locate ---
        j = jnp.floor((q - kmin) * scale).astype(jnp.int32)
        j = jnp.clip(j, 0, (1 << radix_bits))
        t2 = pl.load(rt_ref, (slice(0, 1), pl.ds(j, 2)))
        lo = t2[0, 0]
        hi = jnp.clip(t2[0, 1], lo, jnp.maximum(n_knots - 1, 0))
        # --- branchless windowed segment search ---
        lt = (kk_ref[...] < q) & (kidx >= lo) & (kidx <= hi)
        succ = lo + jnp.sum(lt.astype(jnp.int32))
        seg = jnp.maximum(succ - 1, 0)
        pair_k = pl.load(kk_ref, (slice(0, 1),
                                  pl.ds(jnp.minimum(seg, m_pad - 2), 2)))
        pair_p = pl.load(kp_ref, (slice(0, 1),
                                  pl.ds(jnp.minimum(seg, m_pad - 2), 2)))
        k0, k1 = pair_k[0, 0], pair_k[0, 1]
        p0, p1 = pair_p[0, 0], pair_p[0, 1]
        t = jnp.clip((q - k0) / jnp.maximum(k1 - k0, 1e-30), 0.0, 1.0)
        phat = p0 + t * (p1 - p0)
        # --- eps-bounded probe (exact lower bound) ---
        start = jnp.clip(jnp.round(phat).astype(jnp.int32) - probe // 2,
                         0, n_pad - probe)
        win = pl.load(keys_ref, (slice(0, 1), pl.ds(start, probe)))
        pos = start + jnp.sum((win < q).astype(jnp.int32))
        pos = jnp.minimum(pos, count)
        out_ref[slice(0, 1), pl.ds(i, 1)] = pos.reshape(1, 1)
        return 0

    jax.lax.fori_loop(0, QBLOCK, one, 0)


@partial(jax.jit, static_argnames=("probe", "radix_bits", "interpret"))
def spline_search(queries, knot_keys, knot_pos, radix_table, keys_f,
                  scalars, *, probe: int, radix_bits: int, interpret: bool):
    """Lower-bound positions for a batch of query keys on ONE partition.

    queries:   (Q,) f32, Q % QBLOCK == 0
    knot_keys/knot_pos: (M,) f32 ; radix_table: (R,) int32
    keys_f:    (N,) f32 sorted (sentinel-padded)
    scalars:   (1, 8) f32 [kmin, scale, n_knots, count, ...]
    """
    q = queries.reshape(1, -1)
    nq = q.shape[1]
    assert nq % QBLOCK == 0
    m = knot_keys.shape[0]
    n = keys_f.shape[0]
    r = radix_table.shape[0]
    grid = (nq // QBLOCK,)
    out = pl.pallas_call(
        partial(_kernel, probe=probe, radix_bits=radix_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nq), jnp.int32),
        interpret=interpret,
    )(scalars, q, knot_keys.reshape(1, -1), knot_pos.reshape(1, -1),
      radix_table.reshape(1, -1), keys_f.reshape(1, -1))
    return out.reshape(-1)
