"""Pallas TPU kernel: point-probe — window equality scan after the
learned lookup (paper Alg. 3's bidirectional duplicate-run scan
collapsed into one masked reduction).

The point query is query-centric: each query probes the <= probe-wide
window around its learned position in ITS candidate partition, so the
scan's natural tile is the batch of gathered windows (Q, probe) — not
a partition plane. The host gathers the per-query key/x/y windows
(cheap dynamic slices) and the kernel reduces each (QB, probe_pad)
tile to per-query match counts in one launch per batch. Grid is the
query axis only; the window axis is VMEM-resident.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import iota2

QB = 128


def _kernel(q_ref, wk_ref, wx_ref, wy_ref, out_ref, *, probe: int):
    lane = iota2((1, wk_ref.shape[1]), 1)
    m = ((lane < probe) &
         (wk_ref[...] == q_ref[:, 0:1]) &
         (wx_ref[...] == q_ref[:, 1:2]) &
         (wy_ref[...] == q_ref[:, 2:3]))
    out_ref[...] = jnp.sum(m.astype(jnp.int32), axis=1, keepdims=True)


@partial(jax.jit, static_argnames=("probe", "interpret"))
def point_probe(q3, wk, wx, wy, *, probe: int, interpret: bool):
    """Exact-match counts in each query's gathered probe window.

    q3: (Q, 4) f32 [key, x, y, pad] ; wk, wx, wy: (Q, W) f32 windows
    (W >= probe, lanes >= probe are padding). Returns (Q,) int32 match
    counts (found iff > 0).
    """
    nq = q3.shape[0]
    w = wk.shape[1]
    assert nq % QB == 0
    grid = (nq // QB,)
    out = pl.pallas_call(
        partial(_kernel, probe=probe),
        grid=grid,
        in_specs=[
            pl.BlockSpec((QB, 4), lambda i: (i, 0)),
            pl.BlockSpec((QB, w), lambda i: (i, 0)),
            pl.BlockSpec((QB, w), lambda i: (i, 0)),
            pl.BlockSpec((QB, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((QB, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        interpret=interpret,
    )(q3, wk, wx, wy)
    return out.reshape(-1)
