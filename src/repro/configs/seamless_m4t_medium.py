"""seamless-m4t-medium [audio] — enc-dec, 12L encoder + 12L decoder,
d=1024 16H d_ff=4096 vocab=256206. The speech frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, S, 1024).
[arXiv:2308.11596]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        vocab=256206, d_model=1024,
        n_layers=24, enc_layers=12, dec_layers=12,
        n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096,
        frame_input=True, frame_dim=1024,
        rope_theta=1e4, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="encdec",
        vocab=512, d_model=64,
        n_layers=4, enc_layers=2, dec_layers=2,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192,
        frame_input=True, frame_dim=32,
        max_seq=256,
    )
