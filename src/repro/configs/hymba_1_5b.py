"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) d_ff=5504,
vocab=32001, parallel attention + mamba(SSD) heads, ssm_state=16;
sliding-window attention except first/middle/last layers (global).
[arXiv:2411.13676]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hymba",
        vocab=32001, d_model=1600, n_layers=32,
        n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504,
        window=1024, global_layers=(0, 15, 31),
        ssm_heads=25, ssm_head_dim=64, ssm_state=16,
        rope_theta=1e4, max_seq=1 << 20,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        family="hymba",
        vocab=512, d_model=64, n_layers=3,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192,
        window=16, global_layers=(0, 2),
        ssm_heads=4, ssm_head_dim=16, ssm_state=8,
        max_seq=512,
    )
