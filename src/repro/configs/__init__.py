"""Assigned architecture configs (+ the paper's own spatial workloads).

Each module exposes ``config()`` (full published size) and
``smoke_config()`` (same family, reduced dims, CPU-testable). The
registry maps ``--arch <id>`` strings used by launch/ and benchmarks/.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_v2_lite_16b",
    "dbrx_132b",
    "rwkv6_3b",
    "minicpm3_4b",
    "internlm2_20b",
    "qwen2_5_3b",
    "gemma3_4b",
    "seamless_m4t_medium",
    "hymba_1_5b",
    "phi_3_vision_4_2b",
]

# canonical dashed ids (CLI) -> module names
def _mod_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_mod_name(arch_id)}")
    return mod.smoke_config() if smoke else mod.config()


def all_arch_ids():
    return [a.replace("_", "-") for a in ARCHS]
