"""phi-3-vision-4.2b [vlm] — 32L d=3072 32H d_ff=8192 vocab=32064;
phi3-mini backbone + CLIP frontend STUB: input_specs() provides
precomputed patch embeddings (B, 256, 1024), projected and prepended to
the token sequence. [hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="transformer",
        vocab=32064, d_model=3072, n_layers=32,
        n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192,
        patch_input=True, n_patches=256, patch_dim=1024,
        rope_theta=1e4, max_seq=131072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3v-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192,
        patch_input=True, n_patches=8, patch_dim=32,
        max_seq=256,
    )
