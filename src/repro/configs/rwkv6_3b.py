"""rwkv6-3b "Finch" [ssm] — 32L d=2560 attn-free, d_ff=8960,
vocab=65536, data-dependent decay, head size 64. [arXiv:2404.05892]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="rwkv6",
        vocab=65536, d_model=2560, n_layers=32,
        d_ff=8960,
        ssm_heads=40,                    # head size 64
        max_seq=1 << 20,                 # state-based: unbounded context
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="rwkv6",
        vocab=512, d_model=64, n_layers=2,
        d_ff=192,
        ssm_heads=4,
        max_seq=512,
    )
