"""internlm2-20b [dense] — 48L d=6144 48H (GQA kv=8) d_ff=16384,
vocab=92544. [arXiv:2403.17297]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="transformer",
        vocab=92544, d_model=6144, n_layers=48,
        n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384,
        rope_theta=1e6, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192,
        max_seq=256,
    )
