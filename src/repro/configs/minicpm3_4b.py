"""minicpm3-4b [dense] — 62L d=2560 40H, MLA (q_lora=768, kv_lora=256),
d_ff=6400, vocab=73448. [hf:openbmb/MiniCPM3-4B]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="transformer",
        vocab=73448, d_model=2560, n_layers=62,
        n_heads=40, n_kv_heads=40,
        attn="mla", q_lora=768, kv_lora=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        d_ff=6400,
        rope_theta=1e4, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=3,
        n_heads=4, n_kv_heads=4,
        attn="mla", q_lora=48, kv_lora=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        d_ff=192,
        max_seq=256,
    )
