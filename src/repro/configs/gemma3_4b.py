"""gemma3-4b [dense] — 34L d=2560 8H (GQA kv=4) d_ff=10240,
vocab=262144, 5:1 local:global sliding-window (1024), 128k context.
[hf:google/gemma-3-4b-pt]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="transformer",
        vocab=262144, d_model=2560, n_layers=34,
        n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240,
        window=1024, global_every=6,      # layers 5, 11, ... are global
        tie_embeddings=True,
        rope_theta=1e6, max_seq=131072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=6,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192,
        window=16, global_every=3,
        tie_embeddings=True,
        max_seq=256,
    )
