"""qwen2.5-3b [dense] — 36L d=2048 16H (GQA kv=2) d_ff=11008,
vocab=151936, QKV bias. [hf:Qwen/Qwen2.5-3B]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="transformer",
        vocab=151936, d_model=2048, n_layers=36,
        n_heads=16, n_kv_heads=2, head_dim=128,
        d_ff=11008, qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, qkv_bias=True,
        tie_embeddings=True,
        max_seq=256,
    )
