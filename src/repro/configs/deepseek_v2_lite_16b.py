"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + fine-grained MoE.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, 64 routed experts
top-6 + 2 shared, first layer dense FFN (d_ff 10944). [arXiv:2405.04434]
(The assignment sheet's bracket note "160 routed" belongs to the full
V2; the lite config above matches the published HF config.)
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="transformer",
        vocab=102400, d_model=2048, n_layers=27,
        n_heads=16, n_kv_heads=16, head_dim=128,
        attn="mla", q_lora=0, kv_lora=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        d_ff=10944,
        moe=True, n_experts=64, n_shared=2, top_k=6, d_expert=1408,
        first_dense=1, d_ff_dense=10944,
        rope_theta=1e4, max_seq=163840,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=3,
        n_heads=4, n_kv_heads=4, head_dim=16,
        attn="mla", q_lora=0, kv_lora=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        d_ff=192,
        moe=True, n_experts=8, n_shared=2, top_k=2, d_expert=48,
        first_dense=1, d_ff_dense=192,
        max_seq=256,
    )
