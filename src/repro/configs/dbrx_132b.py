"""dbrx-132b [moe] — 40L d=6144 48H (GQA kv=8) d_ff=10752/expert,
16 experts top-4, vocab=100352. [hf:databricks/dbrx-base]
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="transformer",
        vocab=100352, d_model=6144, n_layers=40,
        n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=10752,
        moe=True, n_experts=16, n_shared=0, top_k=4, d_expert=10752,
        rope_theta=5e5, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke",
        family="transformer",
        vocab=512, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128,
        moe=True, n_experts=4, n_shared=0, top_k=2, d_expert=128,
        max_seq=256,
    )
