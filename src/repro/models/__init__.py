"""Model zoo for the assigned architectures.

Families: transformer (GQA / MLA / MoE / sliding-window / enc-dec / VLM
stub), rwkv6 (attention-free), hymba (parallel attention + SSM heads).
All models expose the same functional API via ``registry.build_model``:

  init(rng) -> params
  train_loss(params, batch) -> scalar loss
  prefill(params, batch) -> (logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)
"""
from repro.models.registry import build_model  # noqa: F401
