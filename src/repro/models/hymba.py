"""Hymba: hybrid-head layers — attention heads and Mamba2-style SSD heads
run IN PARALLEL on the same residual input; their outputs fuse by
averaging (paper's mean-fusion, learnable scaling omitted — noted in
DESIGN.md). Most layers use sliding-window attention; first/middle/last
are global (cfg.global_layers).

SSD branch: scalar per-head decay a*dt (Mamba2), state (N x P) per head,
computed chunkwise via the shared linear_attn scan. The decay-shift trick
(q premultiplied by exp(a*dt)) converts the "decay applies to current
state" SSM convention into the linear-attn form; the current-token
(diagonal) term is added in closed form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.common import (ModelConfig, init_params, rms_norm,
                                 softmax_xent, swiglu)
from repro.models.linear_attn import chunked_linear_attn
from repro.models.transformer import (GLOBAL_WINDOW, _checkpoint,
                                      window_array)
from repro.sharding import constrain


def _ssd_project(p, x, cfg: ModelConfig):
    b, t, _ = x.shape
    hm, pp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs = (x @ p["wx"].astype(x.dtype)).reshape(b, t, hm, pp)
    bt = x @ p["wb"].astype(x.dtype)                 # (B,T,N)
    ct = x @ p["wc"].astype(x.dtype)
    dt = jax.nn.softplus(
        (x @ p["wdt"].astype(x.dtype)).astype(jnp.float32))  # (B,T,Hm)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))     # (Hm,)
    logw = a[None, None] * dt                        # (B,T,Hm) <= 0
    return xs, bt, ct, dt, logw


def ssd_branch(p, x, cfg: ModelConfig, state=None):
    """Mamba2-SSD over the full sequence. Returns (out, final state)."""
    b, t, _ = x.shape
    hm, pp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs, bt, ct, dt, logw = _ssd_project(p, x, cfg)
    v = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    k = jnp.broadcast_to(bt[:, :, None, :], (b, t, hm, n)).astype(x.dtype)
    q_raw = jnp.broadcast_to(ct[:, :, None, :], (b, t, hm, n))
    # decay-shift: current-state convention -> linear-attn convention
    q = (q_raw.astype(jnp.float32) * jnp.exp(logw)[..., None]).astype(
        x.dtype)
    lw = jnp.broadcast_to(logw[..., None], (b, t, hm, n))
    out, new_state = chunked_linear_attn(q, k, v, lw, state=state)
    # diagonal (current token): C.B * (dt x)
    diag = jnp.einsum("btn,btn->bt", ct.astype(jnp.float32),
                      bt.astype(jnp.float32))
    out = out + (diag[:, :, None, None] * v.astype(jnp.float32)).astype(
        out.dtype)
    out = out + p["dskip"].astype(out.dtype)[None, None] * xs
    out = rms_norm(out.reshape(b, t, hm * pp), p["norm"], cfg.norm_eps)
    return out @ p["wo"].astype(x.dtype), new_state


def ssd_decode(p, x, cfg: ModelConfig, state):
    """One-token SSD: h = e^{a dt} h + dt B x ; y = C h + D x."""
    b = x.shape[0]
    hm, pp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs, bt, ct, dt, logw = _ssd_project(p, x, cfg)
    w = jnp.exp(logw[:, 0])                               # (B,Hm)
    kv = jnp.einsum("bn,bhp->bhnp", bt[:, 0].astype(jnp.float32),
                    (xs[:, 0].astype(jnp.float32) *
                     dt[:, 0][..., None]))
    new_state = w[..., None, None] * state + kv
    y = jnp.einsum("bn,bhnp->bhp", ct[:, 0].astype(jnp.float32),
                   new_state)
    y = y + p["dskip"].astype(jnp.float32)[None] * xs[:, 0].astype(
        jnp.float32)
    y = rms_norm(y.reshape(b, 1, hm * pp).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    return y @ p["wo"].astype(x.dtype), new_state


class HymbaModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng):
        return init_params(self.cfg, rng)

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        ln = cfg.n_layers
        return {
            "k": jnp.zeros((ln, batch_size, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.cdtype),
            "v": jnp.zeros((ln, batch_size, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.cdtype),
            "ssm": jnp.zeros((ln, batch_size, cfg.ssm_heads,
                              cfg.ssm_state, cfg.ssm_head_dim),
                             jnp.float32),
        }

    def _layer_full(self, lp, x, positions, w, qc, kc, ssm_state):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn_out, kv = A.gqa_attn(lp["attn"], h, cfg, positions=positions,
                                  window=w, q_chunk=qc, kv_chunk=kc)
        ssm_out, new_ssm = ssd_branch(lp["ssm"], h, cfg, state=ssm_state)
        x = x + 0.5 * constrain(attn_out + ssm_out, "batch", None, None)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = lp["ffn"]
        x = x + swiglu(h, f["w1"].astype(h.dtype), f["w3"].astype(h.dtype),
                       f["w2"].astype(h.dtype))
        return x, kv, new_ssm

    def forward(self, params, batch, *, remat=False, collect_cache=False):
        cfg = self.cfg
        tok = batch["tokens"]
        x = params["embed"].astype(cfg.cdtype)[tok]
        x = constrain(x, "batch", None, None)
        b, s = tok.shape
        positions = jnp.arange(s, dtype=jnp.int32)
        qc, kc = min(512, s), min(1024, s)
        wins = window_array(cfg, cfg.n_layers)
        ssm0 = jnp.zeros((cfg.n_layers, b, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32)

        def body(xc, xs):
            lp, w, st = xs
            xc, kv, new_ssm = self._layer_full(lp, xc, positions, w, qc,
                                               kc, st)
            return xc, (kv, new_ssm) if collect_cache else None

        body_fn = _checkpoint(body) if remat else body
        x, ys = jax.lax.scan(body_fn, x, (params["layers"], wins, ssm0))
        if collect_cache:
            x = x[:, -1:]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = constrain(x @ params["lm_head"].astype(cfg.cdtype),
                           "batch", None, "tp")
        return logits, ys

    def train_loss(self, params, batch):
        logits, _ = self.forward(params, batch, remat=True)
        return softmax_xent(logits, batch["labels"], batch["mask"])

    def prefill(self, params, batch, max_len: Optional[int] = None):
        s = batch["tokens"].shape[1]
        max_len = max_len or s
        logits, ys = self.forward(params, batch, collect_cache=True)
        (k, v), ssm = ys

        def pad_s(a):
            if a.shape[2] >= max_len:
                return a
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - a.shape[2])
            return jnp.pad(a, pad)

        return logits, {"k": pad_s(k), "v": pad_s(v), "ssm": ssm}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[tokens]
        wins = window_array(cfg, cfg.n_layers)

        def body(xc, xs):
            lp, ck, cv, st, w = xs
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            attn_out, new_kv = A.gqa_decode(lp["attn"], h, cfg, cache_k=ck,
                                            cache_v=cv, pos=pos, window=w)
            ssm_out, new_st = ssd_decode(lp["ssm"], h, cfg, st)
            xc = xc + 0.5 * (attn_out + ssm_out)
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            f = lp["ffn"]
            xc = xc + swiglu(h, f["w1"].astype(h.dtype),
                             f["w3"].astype(h.dtype),
                             f["w2"].astype(h.dtype))
            return xc, (new_kv[0], new_kv[1], new_st)

        x, news = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["ssm"], wins))
        cache = {"k": news[0], "v": news[1], "ssm": news[2]}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = constrain(x @ params["lm_head"].astype(cfg.cdtype),
                           "batch", None, "tp")
        return logits, cache
