"""RWKV6 "Finch": attention-free LM with data-dependent decay.

Time-mix: per-channel data-dependent decay w_t = exp(-exp(d_t)) where
d_t comes from a low-rank MLP of the token-shift-interpolated input
(the Finch contribution), plus the per-head bonus "u" for the current
token. The sequential wkv recurrence runs CHUNKED (linear_attn.py):
T/64 sequential steps of MXU matmuls instead of T scalar steps — the
TPU-native adaptation of the CUDA wkv kernel.

Decode state per layer: wkv state (B,H,D,D) f32 + last-token shift
buffers — O(1) in sequence length, which is why this arch serves the
long_500k shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, init_params, rms_norm,
                                 softmax_xent)
from repro.models.linear_attn import chunked_linear_attn, linear_attn_decode
from repro.sharding import constrain


def _token_shift(x, last):
    """x: (B,T,d); last: (B,1,d) from the previous segment."""
    prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return prev


def _time_mix(p, x, last, cfg: ModelConfig, state):
    b, t, d = x.shape
    h = cfg.ssm_heads
    hd = d // h
    prev = _token_shift(x, last)
    mix = p["mix_x"].astype(x.dtype)              # (5, d)
    xr = x + (prev - x) * mix[0]
    xk = x + (prev - x) * mix[1]
    xv = x + (prev - x) * mix[2]
    xg = x + (prev - x) * mix[3]
    xw = x + (prev - x) * mix[4]

    r = (xr @ p["wr"].astype(x.dtype)).reshape(b, t, h, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(b, t, h, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    # data-dependent decay (low-rank): logw in (-inf, 0)
    dd = jnp.tanh(xw @ p["wd1"].astype(x.dtype)) @ p["wd2"].astype(x.dtype)
    logw = -jnp.exp(jnp.clip(
        p["decay_base"].astype(jnp.float32).reshape(1, 1, h, hd) +
        dd.astype(jnp.float32).reshape(b, t, h, hd), -8.0, 4.0))
    bonus = p["bonus"].astype(jnp.float32)

    out, new_state = chunked_linear_attn(r, k, v, logw, state=state,
                                         bonus=bonus)
    out = out.reshape(b, t, d)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps) * g
    return out @ p["wo"].astype(x.dtype), new_state, x[:, -1:]


def _channel_mix(p, x, last, cfg: ModelConfig):
    prev = _token_shift(x, last)
    mix = p["mix_c"].astype(x.dtype)
    xk = x + (prev - x) * mix[0]
    xr = x + (prev - x) * mix[1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    return jax.nn.sigmoid(xr) * (k @ p["cv"].astype(x.dtype)), x[:, -1:]


class RWKV6Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng):
        return init_params(self.cfg, rng)

    def init_state(self, batch_size: int):
        cfg = self.cfg
        d = cfg.d_model
        h = cfg.ssm_heads
        hd = d // h
        ln = cfg.n_layers
        return {
            "wkv": jnp.zeros((ln, batch_size, h, hd, hd), jnp.float32),
            "shift_t": jnp.zeros((ln, batch_size, 1, d), cfg.cdtype),
            "shift_c": jnp.zeros((ln, batch_size, 1, d), cfg.cdtype),
        }

    def _forward(self, params, tokens, state, *, remat: bool = False,
                 last_only: bool = False):
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[tokens]
        x = constrain(x, "batch", None, None)

        def body(carry, xs):
            xc = carry
            lp, wkv, sh_t, sh_c = xs
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            out, wkv2, sh_t2 = _time_mix(lp, h, sh_t, cfg, wkv)
            xc = xc + constrain(out, "batch", None, None)
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            out, sh_c2 = _channel_mix(lp, h, sh_c, cfg)
            xc = xc + constrain(out, "batch", None, None)
            return xc, (wkv2, sh_t2, sh_c2)

        body_fn = jax.checkpoint(body) if remat else body
        x, news = jax.lax.scan(
            body_fn, x, (params["layers"], state["wkv"],
                         state["shift_t"], state["shift_c"]))
        if last_only:
            x = x[:, -1:]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["lm_head"].astype(cfg.cdtype)
        logits = constrain(x @ head, "batch", None, "tp")
        new_state = {"wkv": news[0], "shift_t": news[1], "shift_c": news[2]}
        return logits, new_state

    def train_loss(self, params, batch):
        state = self.init_state(batch["tokens"].shape[0])
        logits, _ = self._forward(params, batch["tokens"], state,
                                  remat=True)
        return softmax_xent(logits, batch["labels"], batch["mask"])

    def prefill(self, params, batch, max_len: Optional[int] = None):
        state = self.init_state(batch["tokens"].shape[0])
        logits, state = self._forward(params, batch["tokens"], state,
                                      last_only=True)
        return logits, state

    def decode_step(self, params, cache, tokens, pos):
        """State-based decode: cost independent of context length."""
        del pos
        logits, cache = self._forward(params, tokens, cache)
        return logits, cache
