"""Decoder-only transformer (GQA/MLA, MoE, sliding-window) + enc-dec.

Layers are stacked and executed with ``lax.scan`` (bounded compile time at
512-device SPMD lowering — essential on the production mesh) with
``jax.checkpoint`` rematerialization in training. Per-layer sliding-window
sizes ride the scan as a traced (L,) array (global layers get a 2^30
window), so gemma3's 5:1 local:global pattern lives in ONE scan.

DeepSeek-style "first layer dense FFN" layers are unrolled before the
scan (their shapes differ from the MoE stack).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models.common import (ModelConfig, init_params, rms_norm,
                                 softmax_xent, swiglu)
from repro.models.moe import moe_ffn
from repro.sharding import constrain, gather_weight

GLOBAL_WINDOW = 1 << 30

# remat policy toggle (perf hillclimb): which intermediates the
# checkpointed layer scan may keep instead of recomputing
_REMAT = {"policy": None}


def set_remat_policy(name: str):
    table = {
        "none": None,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    _REMAT["policy"] = table[name]


def _checkpoint(fn):
    pol = _REMAT["policy"]
    if pol is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=pol)


def window_array(cfg: ModelConfig, n_layers: int, offset: int = 0):
    return jnp.asarray(
        [cfg.window_for_layer(i + offset) or GLOBAL_WINDOW
         for i in range(n_layers)], jnp.int32)


class TransformerModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params -----------------------------------------------------------

    def init(self, rng):
        return init_params(self.cfg, rng)

    # -- embedding / head ---------------------------------------------------

    def _embed(self, params, batch):
        cfg = self.cfg
        tok = batch["tokens"]
        x = params["embed"].astype(cfg.cdtype)[tok]
        if cfg.patch_input and "patches" in batch:
            pe = batch["patches"].astype(cfg.cdtype) @ \
                params["patch_proj"].astype(cfg.cdtype)
            x = jnp.concatenate([pe, x], axis=1)
        x = constrain(x, "batch", None, None)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cfg.cdtype)
        head = gather_weight(head, None, "tp") if not cfg.tie_embeddings \
            else head
        logits = x @ head
        return constrain(logits, "batch", None, "tp")

    # -- one layer (shared by modes) ----------------------------------------

    def _attn_full(self, p, x, positions, window, qc, kc):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cfg.attn == "mla":
            out, kv = A.mla_attn(p["attn"], h, cfg, positions=positions,
                                 q_chunk=qc, kv_chunk=kc)
        else:
            out, kv = A.gqa_attn(p["attn"], h, cfg, positions=positions,
                                 window=window, q_chunk=qc, kv_chunk=kc)
        return x + constrain(out, "batch", None, None), kv

    def _ffn(self, p, x):
        cfg = self.cfg
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            out, aux = moe_ffn(p["moe"], h, cfg)
        else:
            f = p["ffn"]
            out = swiglu(h,
                         gather_weight(f["w1"].astype(h.dtype), None,
                                       "tp"),
                         gather_weight(f["w3"].astype(h.dtype), None,
                                       "tp"),
                         gather_weight(f["w2"].astype(h.dtype), "tp",
                                       None))
            aux = jnp.float32(0.0)
        return x + constrain(out, "batch", None, None), aux

    def _layer_full(self, p, x, positions, window, qc, kc):
        x, kv = self._attn_full(p, x, positions, window, qc, kc)
        x, aux = self._ffn(p, x)
        return x, kv, aux

    # -- full-sequence forward (train / prefill) -----------------------------

    def forward(self, params, batch, *, remat: bool = False,
                collect_cache: bool = False):
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        qc = min(512, s)
        kc = min(1024, s)
        aux_total = jnp.float32(0.0)
        fd_kv = []
        for i in range(cfg.first_dense):
            x, kv, aux = self._layer_full(params[f"layer{i}"], x,
                                          positions,
                                          cfg.window_for_layer(i)
                                          or GLOBAL_WINDOW, qc, kc)
            aux_total += aux
            fd_kv.append(kv)

        n_scan = cfg.n_layers - cfg.first_dense
        wins = window_array(cfg, n_scan, offset=cfg.first_dense)

        def body(carry, xs):
            xc, auxc = carry
            lp, w = xs
            xc, kv, aux = self._layer_full(lp, xc, positions, w, qc, kc)
            out = kv if collect_cache else None
            return (xc, auxc + aux), out

        body_fn = _checkpoint(body) if remat else body
        (x, aux_total), kvs = jax.lax.scan(
            body_fn, (x, aux_total), (params["layers"], wins))
        if collect_cache:
            x = x[:, -1:]          # prefill only needs last-token logits
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        if not collect_cache:
            return logits, aux_total
        return logits, aux_total, fd_kv, kvs

    # -- training -----------------------------------------------------------

    def train_loss(self, params, batch):
        logits, aux = self.forward(params, batch, remat=True)
        loss = softmax_xent(logits, batch["labels"], batch["mask"])
        return loss + 0.01 * aux

    # -- serving ------------------------------------------------------------

    def _stack_cache(self, fd_kv, kvs, max_len):
        cfg = self.cfg

        def pad_s(a):
            s = a.shape[2]
            if s >= max_len:
                return a
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - s)
            return jnp.pad(a, pad)

        if cfg.attn == "mla":
            cs = kvs[0] if not fd_kv else jnp.concatenate(
                [jnp.stack([kv[0] for kv in fd_kv]), kvs[0]], axis=0)
            rs = kvs[1] if not fd_kv else jnp.concatenate(
                [jnp.stack([kv[1] for kv in fd_kv]), kvs[1]], axis=0)
            return {"c": pad_s(cs), "rope": pad_s(rs)}
        ks, vs = kvs
        if fd_kv:
            ks = jnp.concatenate([jnp.stack([kv[0] for kv in fd_kv]), ks],
                                 axis=0)
            vs = jnp.concatenate([jnp.stack([kv[1] for kv in fd_kv]), vs],
                                 axis=0)
        return {"k": pad_s(ks), "v": pad_s(vs)}

    def init_cache(self, batch_size: int, max_len: int):
        """Empty decode cache (for decode-only lowering)."""
        cfg = self.cfg
        ln = cfg.n_layers
        if cfg.attn == "mla":
            return {
                "c": jnp.zeros((ln, batch_size, max_len, cfg.kv_lora),
                               cfg.cdtype),
                "rope": jnp.zeros((ln, batch_size, max_len,
                                   cfg.qk_rope_dim), cfg.cdtype),
            }
        return {
            "k": jnp.zeros((ln, batch_size, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.cdtype),
            "v": jnp.zeros((ln, batch_size, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.cdtype),
        }

    def prefill(self, params, batch, max_len: Optional[int] = None):
        s = batch["tokens"].shape[1] + (
            self.cfg.n_patches if (self.cfg.patch_input and
                                   "patches" in batch) else 0)
        max_len = max_len or s
        logits, _, fd_kv, kvs = self.forward(params, batch,
                                             collect_cache=True)
        cache = self._stack_cache(fd_kv, kvs, max_len)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B,1), pos () int32 -> (logits (B,1,V), cache)."""
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[tokens]
        fd = cfg.first_dense
        n_scan = cfg.n_layers - fd
        wins = window_array(cfg, n_scan, offset=fd)

        def attn_dec(p, xc, cache_i, w):
            h = rms_norm(xc, p["ln1"], cfg.norm_eps)
            if cfg.attn == "mla":
                out, new = A.mla_decode(p["attn"], h, cfg,
                                        cache_c=cache_i[0],
                                        cache_rope=cache_i[1], pos=pos)
            else:
                out, new = A.gqa_decode(p["attn"], h, cfg,
                                        cache_k=cache_i[0],
                                        cache_v=cache_i[1], pos=pos,
                                        window=w)
            xc = xc + out
            xc, _ = self._ffn(p, xc)
            return xc, new

        names = ("c", "rope") if cfg.attn == "mla" else ("k", "v")
        for i in range(fd):
            ci = (cache[names[0]][i], cache[names[1]][i])
            x, new = attn_dec(params[f"layer{i}"], x, ci,
                              cfg.window_for_layer(i) or GLOBAL_WINDOW)
            cache = {
                names[0]: cache[names[0]].at[i].set(new[0]),
                names[1]: cache[names[1]].at[i].set(new[1]),
            }

        def body(xc, xs):
            lp, c0, c1, w = xs
            xc, new = attn_dec(lp, xc, (c0, c1), w)
            return xc, new

        x, news = jax.lax.scan(
            body, x, (params["layers"], cache[names[0]][fd:],
                      cache[names[1]][fd:], wins))
        cache = {
            names[0]: jax.lax.dynamic_update_slice_in_dim(
                cache[names[0]], news[0], fd, axis=0),
            names[1]: jax.lax.dynamic_update_slice_in_dim(
                cache[names[1]], news[1], fd, axis=0),
        }
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), cache


# ---------------------------------------------------------------------------
# encoder-decoder (seamless-m4t backbone; audio frontend is a stub)
# ---------------------------------------------------------------------------

class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng):
        return init_params(self.cfg, rng)

    def encode(self, params, frames):
        """frames: (B, Ss, frame_dim) precomputed embeddings (stub)."""
        cfg = self.cfg
        x = frames.astype(cfg.cdtype) @ params["frame_proj"].astype(
            cfg.cdtype)
        x = constrain(x, "batch", None, None)
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        qc, kc = min(512, s), min(1024, s)

        def body(xc, lp):
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            out, _ = A.gqa_attn(lp["attn"], h, cfg, positions=positions,
                                q_chunk=qc, kv_chunk=kc, causal=False)
            xc = xc + out
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            f = lp["ffn"]
            xc = xc + swiglu(h, f["w1"].astype(h.dtype),
                             f["w3"].astype(h.dtype),
                             f["w2"].astype(h.dtype))
            return xc, None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _dec_layer(self, lp, x, mem, positions, mem_len, qc, kc):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, kv = A.gqa_attn(lp["attn"], h, cfg, positions=positions,
                             q_chunk=qc, kv_chunk=kc)
        x = x + out
        # cross attention (no rope on memory)
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q, _, _ = A.gqa_project(lp["xattn"], h, cfg)
        mk = (mem @ lp["xattn"]["wk"].astype(mem.dtype)).reshape(
            mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.head_dim)
        mv = (mem @ lp["xattn"]["wv"].astype(mem.dtype)).reshape(
            mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.head_dim)
        mpos = jnp.arange(mem.shape[1], dtype=jnp.int32)
        out = A.flash_attention(
            q, mk, mv, q_pos=jnp.full((q.shape[1],), mem.shape[1],
                                      jnp.int32),
            k_pos=mpos, kv_len=mem_len, q_chunk=min(512, q.shape[1]),
            kv_chunk=min(1024, mem.shape[1]))
        x = x + out.reshape(x.shape) @ lp["xattn"]["wo"].astype(x.dtype)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = lp["ffn"]
        x = x + swiglu(h, f["w1"].astype(h.dtype), f["w3"].astype(h.dtype),
                       f["w2"].astype(h.dtype))
        return x, kv

    def forward(self, params, batch, collect_cache: bool = False):
        cfg = self.cfg
        mem = self.encode(params, batch["frames"])
        mem_len = batch.get("frame_len")
        tok = batch["tokens"]
        x = params["embed"].astype(cfg.cdtype)[tok]
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        qc, kc = min(512, s), min(1024, s)

        def body(xc, lp):
            xc, kv = self._dec_layer(lp, xc, mem, positions, mem_len,
                                     qc, kc)
            return xc, kv if collect_cache else None

        x, kvs = jax.lax.scan(body, x, params["dec_layers"])
        if collect_cache:
            x = x[:, -1:]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["lm_head"].astype(cfg.cdtype)
        logits = constrain(x @ head, "batch", None, "tp")
        return logits, mem, kvs

    def train_loss(self, params, batch):
        logits, _, _ = self.forward(params, batch)
        return softmax_xent(logits, batch["labels"], batch["mask"])

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        logits, mem, kvs = self.forward(params, batch, collect_cache=True)
        s = batch["tokens"].shape[1]
        max_len = max_len or s
        del s

        def pad_s(a):
            if a.shape[2] >= max_len:
                return a
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - a.shape[2])
            return jnp.pad(a, pad)

        # precompute cross-attn K/V once (per layer, over memory)
        def xkv(lp):
            mk = (mem @ lp["xattn"]["wk"].astype(mem.dtype)).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.head_dim)
            mv = (mem @ lp["xattn"]["wv"].astype(mem.dtype)).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.head_dim)
            return mk, mv

        xk, xv = jax.vmap(xkv)(params["dec_layers"])
        cache = {"k": pad_s(kvs[0]), "v": pad_s(kvs[1]),
                 "xk": xk, "xv": xv}
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int, src_len: int):
        cfg = self.cfg
        ln = cfg.dec_layers
        kvh = cfg.n_kv_heads
        return {
            "k": jnp.zeros((ln, batch_size, max_len, kvh, cfg.head_dim),
                           cfg.cdtype),
            "v": jnp.zeros((ln, batch_size, max_len, kvh, cfg.head_dim),
                           cfg.cdtype),
            "xk": jnp.zeros((ln, batch_size, src_len, kvh, cfg.head_dim),
                            cfg.cdtype),
            "xv": jnp.zeros((ln, batch_size, src_len, kvh, cfg.head_dim),
                            cfg.cdtype),
        }

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[tokens]
        b = x.shape[0]
        g = cfg.n_heads // cfg.n_kv_heads

        def body(xc, xs):
            lp, ck, cv, xk, xv = xs
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            out, new = A.gqa_decode(lp["attn"], h, cfg, cache_k=ck,
                                    cache_v=cv, pos=pos,
                                    window=GLOBAL_WINDOW)
            xc = xc + out
            # cross attention against precomputed memory K/V
            h = rms_norm(xc, lp["ln_x"], cfg.norm_eps)
            q, _, _ = A.gqa_project(lp["xattn"], h, cfg)
            qg = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim) * \
                cfg.head_dim ** -0.5
            s = jnp.einsum("bkgd,bskd->bkgs", qg, xk,
                           preferred_element_type=jnp.float32)
            pattn = jax.nn.softmax(s, axis=-1).astype(xv.dtype)
            ctx = jnp.einsum("bkgs,bskv->bkgv", pattn, xv,
                             preferred_element_type=jnp.float32)
            xc = xc + ctx.reshape(b, 1, -1).astype(xc.dtype) @ \
                lp["xattn"]["wo"].astype(xc.dtype)
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            f = lp["ffn"]
            xc = xc + swiglu(h, f["w1"].astype(h.dtype),
                             f["w3"].astype(h.dtype),
                             f["w2"].astype(h.dtype))
            return xc, new

        x, news = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = dict(cache, k=news[0], v=news[1])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = constrain(x @ params["lm_head"].astype(cfg.cdtype),
                           "batch", None, "tp")
        return logits, cache
