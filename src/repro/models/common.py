"""Shared model components: config, norms, rope, ffn, losses, init."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every supported architecture family."""

    name: str = "model"
    family: str = "transformer"  # transformer | rwkv6 | hymba | encdec
    vocab: int = 32000
    d_model: int = 1024
    n_layers: int = 8
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention flavour
    attn: str = "gqa"            # gqa | mla
    # MLA (DeepSeek-V2 / MiniCPM3)
    q_lora: int = 0              # 0 => full-rank Q projection
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # sliding-window pattern: every `global_every`-th layer is global
    # (gemma3 5:1), or the explicit `global_layers` indices (hymba
    # first/middle/last); other layers use `window`; window == 0 -> all
    # layers global.
    window: int = 0
    global_every: int = 0
    global_layers: Tuple[int, ...] = ()

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 2
    d_expert: int = 0
    first_dense: int = 0         # first K layers use a dense FFN
    d_ff_dense: int = 0
    capacity_factor: float = 1.25

    # SSM / RWKV / hymba
    ssm_state: int = 16
    ssm_heads: int = 0
    ssm_head_dim: int = 64

    # enc-dec (seamless)
    enc_layers: int = 0
    dec_layers: int = 0

    # modality stubs
    patch_input: bool = False    # VLM: precomputed patch embeddings
    n_patches: int = 256
    patch_dim: int = 1024
    frame_input: bool = False    # audio: precomputed frame embeddings
    frame_dim: int = 1024

    max_seq: int = 131072
    compute_dtype: str = "bfloat16"

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def window_for_layer(self, i: int) -> int:
        """0 = global attention; otherwise sliding-window size."""
        if self.window == 0:
            return 0
        if i in self.global_layers:
            return 0
        if self.global_every and (i % self.global_every ==
                                  self.global_every - 1):
            return 0
        return self.window

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        shapes = init_shapes(self)
        is_shape = lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x)  # noqa: E731
        return int(sum(int(np.prod(s)) for s in
                       jax.tree_util.tree_leaves(shapes,
                                                 is_leaf=is_shape)))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        ex = 3 * self.d_model * self.d_expert
        n_moe_layers = self.n_layers - self.first_dense
        inactive = n_moe_layers * ex * (self.n_experts - self.top_k)
        return int(total - inactive)


# ---------------------------------------------------------------------------
# primitive layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(dt)


def rope_tables(positions, dim: int, theta: float):
    """positions (...,) -> (cos, sin) of shape (..., dim/2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, H, D); cos/sin: (B, T, D/2) or (T, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """Gated MLP: silu(x@w1) * (x@w3) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def softmax_xent(logits, labels, mask):
    """Mean CE over masked tokens. logits (B,S,V) f32; labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_shapes(cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    return {"w1": (d, d_ff), "w3": (d, d_ff), "w2": (d_ff, d)}


def _attn_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if cfg.attn == "mla":
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        sh = {
            "w_dkv": (d, cfg.kv_lora + cfg.qk_rope_dim),
            "kv_norm": (cfg.kv_lora,),
            "w_uk": (cfg.kv_lora, cfg.n_heads * cfg.qk_nope_dim),
            "w_uv": (cfg.kv_lora, cfg.n_heads * cfg.v_head_dim),
            "wo": (cfg.n_heads * cfg.v_head_dim, d),
        }
        if cfg.q_lora:
            sh["w_dq"] = (d, cfg.q_lora)
            sh["q_norm"] = (cfg.q_lora,)
            sh["w_uq"] = (cfg.q_lora, cfg.n_heads * qk_dim)
        else:
            sh["wq"] = (d, cfg.n_heads * qk_dim)
        return sh
    sh = {
        "wq": (d, cfg.n_heads * cfg.head_dim),
        "wk": (d, cfg.n_kv_heads * cfg.head_dim),
        "wv": (d, cfg.n_kv_heads * cfg.head_dim),
        "wo": (cfg.n_heads * cfg.head_dim, d),
    }
    if cfg.qkv_bias:
        sh["bq"] = (cfg.n_heads * cfg.head_dim,)
        sh["bk"] = (cfg.n_kv_heads * cfg.head_dim,)
        sh["bv"] = (cfg.n_kv_heads * cfg.head_dim,)
    return sh


def _moe_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    sh = {
        "router": (d, cfg.n_experts),
        "we1": (cfg.n_experts, d, cfg.d_expert),
        "we3": (cfg.n_experts, d, cfg.d_expert),
        "we2": (cfg.n_experts, cfg.d_expert, d),
    }
    if cfg.n_shared:
        f = cfg.d_expert * cfg.n_shared
        sh.update({"ws1": (d, f), "ws3": (d, f), "ws2": (f, d)})
    return sh


def _rwkv_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.ssm_heads
    hd = d // h
    return {
        "ln1": (d,), "ln2": (d,),
        # time-mix: r, k, v, gate, decay projections + per-head bonus
        "mix_x": (5, d),                  # token-shift interpolation
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d),
        "wd1": (d, 64), "wd2": (64, d),   # data-dependent decay (lora)
        "decay_base": (h, hd),
        "bonus": (h, hd),
        "ln_x": (d,),
        "wo": (d, d),
        # channel-mix
        "mix_c": (2, d),
        "ck": (d, cfg.d_ff), "cv": (cfg.d_ff, d),
    }


def _hymba_layer_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hm = cfg.ssm_heads
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    return {
        "ln1": (d,), "ln2": (d,),
        "attn": _attn_shapes(cfg),
        # parallel mamba(SSD) heads on the same residual input
        "ssm": {
            "wx": (d, hm * p), "wb": (d, n), "wc": (d, n),
            "wdt": (d, hm), "a_log": (hm,), "dskip": (hm, p),
            "wo": (hm * p, d), "norm": (hm * p,),
        },
        "ffn": _dense_shapes(cfg, cfg.d_ff),
    }


def transformer_layer_shapes(cfg: ModelConfig, layer_idx: int) -> dict:
    d = cfg.d_model
    sh = {"ln1": (d,), "ln2": (d,), "attn": _attn_shapes(cfg)}
    if cfg.moe and layer_idx >= cfg.first_dense:
        sh["moe"] = _moe_shapes(cfg)
    else:
        d_ff = cfg.d_ff_dense if (cfg.moe and cfg.d_ff_dense) else cfg.d_ff
        sh["ffn"] = _dense_shapes(cfg, d_ff)
    return sh


def _stack(n: int, tree):
    """Prefix every shape tuple in the tree with a layer axis."""
    return jax.tree_util.tree_map(
        lambda s: (n,) + s, tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x))


def init_shapes(cfg: ModelConfig) -> dict:
    """Full parameter shape tree (mirrors init())."""
    d = cfg.d_model
    sh = {"embed": (cfg.vocab, d), "final_norm": (d,)}
    if not cfg.tie_embeddings:
        sh["lm_head"] = (d, cfg.vocab)
    if cfg.patch_input:
        sh["patch_proj"] = (cfg.patch_dim, d)
    if cfg.frame_input:
        sh["frame_proj"] = (cfg.frame_dim, d)
    if cfg.family == "rwkv6":
        sh["layers"] = _stack(cfg.n_layers, _rwkv_shapes(cfg))
    elif cfg.family == "hymba":
        sh["layers"] = _stack(cfg.n_layers, _hymba_layer_shapes(cfg))
    elif cfg.family == "encdec":
        sh["enc_layers"] = _stack(cfg.enc_layers,
                                  transformer_layer_shapes(cfg, 0))
        dec = transformer_layer_shapes(cfg, 0)
        dec["xattn"] = _attn_shapes(cfg)
        dec["ln_x"] = (d,)
        sh["dec_layers"] = _stack(cfg.dec_layers, dec)
        sh["enc_norm"] = (d,)
    else:
        # uniform scanned stack for layers >= first_dense; the first
        # `first_dense` layers (deepseek dense-FFN layer 0) are separate.
        for i in range(cfg.first_dense):
            sh[f"layer{i}"] = transformer_layer_shapes(cfg, i)
        n_scan = cfg.n_layers - cfg.first_dense
        body = transformer_layer_shapes(cfg, cfg.first_dense)
        sh["layers"] = _stack(n_scan, body)
    return sh


def init_params(cfg: ModelConfig, rng) -> dict:
    """Gaussian init; norms start at zero offset (rms_norm adds 1)."""
    shapes = init_shapes(cfg)
    is_shape = lambda x: isinstance(x, tuple) and all(
        isinstance(i, int) for i in x)  # noqa: E731
    leaves, treedef = jax.tree_util.tree_flatten(shapes, is_leaf=is_shape)
    keys = jax.random.split(rng, len(leaves))

    def one(key, shape):
        if len(shape) == 1 or shape[-1] == 1:
            return jnp.zeros(shape, jnp.float32)
        scale = 0.02
        return (jax.random.normal(key, shape, jnp.float32) * scale)

    inits = [one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, inits)
