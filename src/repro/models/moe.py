"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch strategy (TPU-native, no torch-style all_to_all emulation):
tokens are flattened, their (expert, rank) pairs sorted, and each expert
receives its first `capacity` tokens via a static-shape scatter. Expert
matmuls run as a single (E, C, d) x (E, d, f) batched einsum whose expert
axis shards over the `model` mesh axis (expert parallelism); XLA SPMD
inserts the all-to-all at the scatter/gather boundary. Dropped tokens
(over capacity) fall back to the shared-expert/zero path — standard
capacity-factor semantics.

Router: softmax top-k with probability renormalization (DeepSeek-V2
style) + load-balancing auxiliary loss (returned for the train loop).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, swiglu
from repro.sharding import gather_weight


def moe_ffn(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar)."""
    b, t, d = x.shape
    n_tok = b * t
    e = cfg.n_experts
    k = cfg.top_k
    cap = int(max(cfg.capacity_factor * n_tok * k / e, 1))
    # round capacity to a lane-friendly multiple
    cap = -(-cap // 8) * 8

    xf = x.reshape(n_tok, d)
    gates = jax.nn.softmax(
        (xf @ p["router"].astype(x.dtype)).astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(gates, k)               # (N, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True),
                                1e-9)

    # -- load balance aux (Switch-style) --
    me = jnp.mean(gates, axis=0)                          # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (n_tok * k))
    aux = e * jnp.sum(me * ce)

    # -- sort-based, GATHER-only dispatch --
    # Scatters into big sharded buffers lower to full-buffer all-reduces
    # under SPMD (measured: ~5 TB/chip/step on dbrx — EXPERIMENTS.md
    # §Perf iteration 2). Instead, scatter only TINY int32 index maps
    # ((E*cap,) slot->token) and move activations with gathers, which
    # SPMD lowers to all-gather/all-to-all-class collectives.
    flat_e = top_e.reshape(-1)                            # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each dispatch within its expert group
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(n_tok * k) - grp_start[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)  # drop slot
    tok_of = order // k                                    # source token

    # slot -> source token (int map, + sentinel row for empty slots)
    slot_src = jnp.full((e * cap + 1,), n_tok, jnp.int32).at[slot].set(
        tok_of.astype(jnp.int32), mode="drop")
    xf_z = jnp.concatenate([xf, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = xf_z[slot_src[:-1]].reshape(e, cap, d)            # gather

    # -- expert compute: batched over the (model-sharded) expert axis;
    # expert weights re-shard to EP-only at use time (ZeRO-3 gather) so
    # the contraction dims are unsharded -> no activation all-reduce --
    we1 = gather_weight(p["we1"].astype(x.dtype), "expert", None, None)
    we3 = gather_weight(p["we3"].astype(x.dtype), "expert", None, None)
    we2 = gather_weight(p["we2"].astype(x.dtype), "expert", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, we1))
    h = h * jnp.einsum("ecd,edf->ecf", xe, we3)
    ye = jnp.einsum("ecf,efd->ecd", h, we2)

    # -- GATHER-only combine: invert the sort, sum each token's k picks --
    ye_flat = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0)
    val = ye_flat[jnp.where(keep, slot, e * cap)]          # (N*k, d)
    inv = jnp.argsort(order)                               # dispatch of
    val_t = val[inv].reshape(n_tok, k, d)                  # each token
    keep_t = keep[inv].reshape(n_tok, k).astype(x.dtype)
    out = jnp.sum(val_t * (top_p.astype(x.dtype) * keep_t)[..., None],
                  axis=1)

    if cfg.n_shared:
        out = out + swiglu(
            xf, gather_weight(p["ws1"].astype(x.dtype), None, "tp"),
            gather_weight(p["ws3"].astype(x.dtype), None, "tp"),
            gather_weight(p["ws2"].astype(x.dtype), "tp", None))
    return out.reshape(b, t, d), aux
