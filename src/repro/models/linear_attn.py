"""Chunked linear-attention / SSM scan — shared by RWKV6 and Hymba(SSD).

Recurrence (per batch b, head h):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        S: (Dk, Dv)
    o_t = q_t^T S_t  (+ bonus term for RWKV)

computed chunkwise (chunk L): within a chunk the contributions factor into
an intra-chunk masked (q k^T) v matmul plus a cross-chunk q S_0 term, with
cumulative per-channel decay products. This is the TPU-native adaptation
of the CUDA-recurrent kernels (fla/mamba-ssd): sequential depth drops from
T to T/L, and all inner math is MXU matmuls. f32 accumulation throughout
(decay ratios are bounded by clamping log-decay per chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_linear_attn(q, k, v, logw, *, chunk: int = 64, state=None,
                        bonus=None):
    """q, k: (B,T,H,Dk); v: (B,T,H,Dv); logw: (B,T,H,Dk) log-decay <= 0.

    bonus: optional (H, Dk) RWKV "u" — adds u-weighted CURRENT token
    contribution (o_t += (q_t . (u * k_t)) v_t).
    state: optional initial (B,H,Dk,Dv).
    Returns (out (B,T,H,Dv) f32-accumulated cast to q.dtype,
             final state (B,H,Dk,Dv) f32).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    lc = min(chunk, t)
    assert t % lc == 0
    n = t // lc

    qf = q.astype(jnp.float32).reshape(b, n, lc, h, dk)
    kf = k.astype(jnp.float32).reshape(b, n, lc, h, dk)
    vf = v.astype(jnp.float32).reshape(b, n, lc, h, dv)
    # clamp so within-chunk inverse decays stay finite
    lw = jnp.clip(logw.astype(jnp.float32), -60.0, 0.0
                  ).reshape(b, n, lc, h, dk)

    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    idx = jnp.arange(lc)
    causal_strict = (idx[:, None] > idx[None, :]).astype(jnp.float32)

    def step(s, inp):
        qc, kc, vc, lwc = inp                  # (B, lc, H, *)
        # cw_t = prod_{j<t} w_j   (exclusive cumulative log-decay)
        cum = jnp.cumsum(lwc, axis=1)          # inclusive
        cw_excl = cum - lwc                    # exclusive
        cw_end = cum[:, -1:]                   # (B,1,H,Dk) total decay
        q_t = qc * jnp.exp(cw_excl)            # q~
        k_t = kc * jnp.exp(-cum)               # k~ (divide by cw_{i+1})
        k_end = kc * jnp.exp(cw_end - cum)     # k * (cwL / cw_{i+1})
        # intra-chunk: strict-causal (q~ k~^T) V
        att = jnp.einsum("blhd,bmhd->bhlm", q_t, k_t)
        att = att * causal_strict[None, None]
        intra = jnp.einsum("bhlm,bmhv->blhv", att, vc)
        # current-token bonus (RWKV u-term) — the diagonal
        if bonus is not None:
            diag = jnp.einsum("blhd,blhd->blh", qc, bonus[None, None] * kc)
            intra = intra + diag[..., None] * vc
        # cross-chunk: q~ S0
        cross = jnp.einsum("blhd,bhdv->blhv", q_t, s)
        # state update: S = diag(cwL) S0 + k_end^T V
        s_new = (jnp.exp(cw_end[:, 0])[..., None] * s +
                 jnp.einsum("bmhd,bmhv->bhdv", k_end, vc))
        return s_new, intra + cross

    state, outs = jax.lax.scan(
        step, state,
        (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0),
         jnp.moveaxis(vf, 1, 0), jnp.moveaxis(lw, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, dv)
    return out.astype(q.dtype), state


def linear_attn_decode(q, k, v, logw, state, bonus=None):
    """Single-token recurrence. q,k: (B,H,Dk); v: (B,H,Dv);
    state (B,H,Dk,Dv) f32. Returns (out (B,H,Dv), new state)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(jnp.clip(logw.astype(jnp.float32), -60.0, 0.0))
    kv = jnp.einsum("bhd,bhv->bhdv", kf, vf)
    if bonus is not None:
        eff = state + bonus[None, :, :, None] * kv
    else:
        eff = state + kv
    out = jnp.einsum("bhd,bhdv->bhv", qf, eff)
    new_state = w[..., None] * state + kv
    return out.astype(q.dtype), new_state
