"""Model registry: ModelConfig.family -> model class."""
from __future__ import annotations

from repro.models.common import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family == "rwkv6":
        from repro.models.rwkv6 import RWKV6Model
        return RWKV6Model(cfg)
    if cfg.family == "hymba":
        from repro.models.hymba import HymbaModel
        return HymbaModel(cfg)
    if cfg.family == "encdec":
        from repro.models.transformer import EncDecModel
        return EncDecModel(cfg)
    from repro.models.transformer import TransformerModel
    return TransformerModel(cfg)
