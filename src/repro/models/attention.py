"""Attention variants: GQA (flash-style chunked, sliding-window) and MLA.

Everything is a pure function over param dicts. Key design points:

* ``flash_attention`` — blockwise online-softmax attention (lax.scan over
  query and key chunks) so 32k-prefill activations never materialize a
  (S x S) score matrix. Masks are position-based: causal, sliding-window,
  and kv-length (for padded decode caches) — all fixed-shape.
* GQA grouping is done by reshaping q to (B, T, KV, G, D), so kv heads are
  never materialized repeated.
* MLA (DeepSeek-V2): trains in the expanded form; decodes in the ABSORBED
  form with a compressed (kv_lora + rope) cache — the memory saving that
  makes 32k/500k decode caches feasible on a 16 GB chip.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, rope_tables
from repro.sharding import gather_weight, shard_attn_acts

NEG_INF = -1.0e30


GLOBAL_WINDOW = 1 << 30  # "no window": larger than any supported seq


def _mask(q_pos, k_pos, window, kv_len, causal: bool):
    """(Tq, Tk) validity mask from positions. ``window`` may be traced
    (per-layer scanned value); GLOBAL_WINDOW disables it arithmetically."""
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
    else:
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    m &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def flash_attention(q, k, v, *, q_pos, k_pos, window=GLOBAL_WINDOW,
                    kv_len=None, q_chunk: int = 512, kv_chunk: int = 1024,
                    scale: Optional[float] = None, causal: bool = True):
    """Blockwise attention. q: (B,Tq,H,D); k,v: (B,Tk,KV,Dk/Dv).

    Returns (B, Tq, H, Dv). H must be a multiple of KV (GQA groups).
    """
    b, tq, h, d = q.shape
    _, tk, kv, dv = v.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qc = min(q_chunk, tq)
    kc = min(kv_chunk, tk)
    assert tq % qc == 0 and tk % kc == 0
    nq, nk = tq // qc, tk // kc

    qg = (q.reshape(b, nq, qc, kv, g, d) * scale).astype(q.dtype)
    kg = k.reshape(b, nk, kc, kv, d)
    vg = v.reshape(b, nk, kc, kv, dv)
    qp = q_pos.reshape(nq, qc)
    kp = k_pos.reshape(nk, kc)

    def q_step(_, qi):
        qblk, qpos = qi                       # (B,qc,KV,G,D), (qc,)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32)
            mask = _mask(qpos, kpos, window, kv_len, causal)  # (qc, kc)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckv->bkgqv", p.astype(vblk.dtype),
                            vblk, preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        init = (jnp.full((b, kv, g, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, kv, g, qc), jnp.float32),
                jnp.zeros((b, kv, g, qc, dv), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, init,
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0), kp))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(q.dtype)                  # (B,KV,G,qc,Dv)

    _, outs = jax.lax.scan(q_step, None, (jnp.moveaxis(qg, 1, 0), qp))
    # (nq, B, KV, G, qc, Dv) -> (B, Tq, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 4, 1, 2, 3, 5)
    return out.reshape(b, tq, h, dv)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_project(p, x, cfg: ModelConfig):
    b, t, _ = x.shape
    q = x @ gather_weight(p["wq"].astype(x.dtype), None, "tp")
    k = x @ gather_weight(p["wk"].astype(x.dtype), None, "tp")
    v = x @ gather_weight(p["wv"].astype(x.dtype), None, "tp")
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def gqa_attn(p, x, cfg: ModelConfig, *, positions, window=GLOBAL_WINDOW,
             cache=None, q_chunk=512, kv_chunk=1024, causal: bool = True):
    """Full-sequence (train/prefill) GQA. Returns (out, (k, v))."""
    b, t, _ = x.shape
    q, k, v = gqa_project(p, x, cfg)
    cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = shard_attn_acts(apply_rope(q, cos, sin), cfg.n_heads)
    k = shard_attn_acts(apply_rope(k, cos, sin), cfg.n_heads)
    v = shard_attn_acts(v, cfg.n_heads)
    out = flash_attention(q, k, v, q_pos=positions, k_pos=positions,
                          window=window, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, causal=causal)
    out = shard_attn_acts(out, cfg.n_heads)
    out = out.reshape(b, t, -1) @ gather_weight(
        p["wo"].astype(x.dtype), "tp", None)
    return out, (k, v)


def gqa_decode(p, x, cfg: ModelConfig, *, cache_k, cache_v, pos,
               window=GLOBAL_WINDOW):
    """One-token decode against a padded cache. x: (B,1,d)."""
    b = x.shape[0]
    s_max = cache_k.shape[1]
    q, k, v = gqa_project(p, x, cfg)
    cos, sin = rope_tables(pos[None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    kv_pos = jnp.arange(s_max, dtype=jnp.int32)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim) * cfg.head_dim**-0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32)
    valid = (kv_pos <= pos) & ((pos - kv_pos) < window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    ctx = jnp.einsum("bkgs,bskv->bkgv", pattn, cache_v,
                     preferred_element_type=jnp.float32)
    out = ctx.reshape(b, 1, -1).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def _mla_q(p, x, cfg: ModelConfig, positions):
    from repro.models.common import rms_norm
    b, t, _ = x.shape
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora:
        cq = rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"],
                      cfg.norm_eps)
        q = cq @ p["w_uq"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(b, t, cfg.n_heads, qk)
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim:]
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope, (cos, sin)


def mla_compress(p, x, cfg: ModelConfig, positions):
    """x -> (c_kv normed (B,T,r), k_rope (B,T,1,rope))."""
    from repro.models.common import rms_norm
    ckv = x @ p["w_dkv"].astype(x.dtype)
    c, k_rope = ckv[..., :cfg.kv_lora], ckv[..., cfg.kv_lora:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)
    return c, k_rope


def mla_attn(p, x, cfg: ModelConfig, *, positions, q_chunk=512,
             kv_chunk=1024):
    """Expanded-form MLA (train / prefill). Returns (out, (c, k_rope))."""
    b, t, _ = x.shape
    q_nope, q_rope, _ = _mla_q(p, x, cfg, positions)
    c, k_rope = mla_compress(p, x, cfg, positions)
    k_nope = (c @ gather_weight(p["w_uk"].astype(x.dtype), None, "tp")
              ).reshape(b, t, cfg.n_heads, cfg.qk_nope_dim)
    v = (c @ gather_weight(p["w_uv"].astype(x.dtype), None, "tp")
         ).reshape(b, t, cfg.n_heads, cfg.v_head_dim)
    # seq-TP fallback measured HARMFUL for MLA (minicpm3: tl 3.7->139 s;
    # EXPERIMENTS.md §Perf) — MLA keeps propagation-derived sharding
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope[..., :cfg.qk_rope_dim].shape
                                  [:3] + (cfg.qk_rope_dim,))], axis=-1)
    out = flash_attention(q, k, v, q_pos=positions, k_pos=positions,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, t, -1) @ gather_weight(
        p["wo"].astype(x.dtype), "tp", None)
    return out, (c, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg: ModelConfig, *, cache_c, cache_rope, pos):
    """Absorbed-form one-token decode. cache_c: (B,S,r); cache_rope:
    (B,S,rope). The per-token cache is r + rope floats (vs 2*H*D for GQA).
    """
    b = x.shape[0]
    s_max = cache_c.shape[1]
    q_nope, q_rope, _ = _mla_q(p, x, cfg, pos[None])
    c_new, k_rope_new = mla_compress(p, x, cfg, pos[None])
    cache_c = jax.lax.dynamic_update_slice(
        cache_c, c_new.astype(cache_c.dtype), (0, pos, 0))
    cache_rope = jax.lax.dynamic_update_slice(
        cache_rope, k_rope_new[:, :, 0, :].astype(cache_rope.dtype),
        (0, pos, 0))
    # absorb w_uk into q: (B,1,H,nope) x (r,H,nope) -> (B,H,r)
    w_uk = p["w_uk"].astype(x.dtype).reshape(
        cfg.kv_lora, cfg.n_heads, cfg.qk_nope_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, cache_c,
                    preferred_element_type=jnp.float32) +
         jnp.einsum("bhp,bsp->bhs", q_rope[:, 0], cache_rope,
                    preferred_element_type=jnp.float32))
    s = s * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    kv_pos = jnp.arange(s_max, dtype=jnp.int32)
    s = jnp.where((kv_pos <= pos)[None, None], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(cache_c.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn, cache_c,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    w_uv = p["w_uv"].astype(x.dtype).reshape(
        cfg.kv_lora, cfg.n_heads, cfg.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv)
    out = o.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, (cache_c, cache_rope)
