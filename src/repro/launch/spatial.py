"""Spatial analytics driver — the paper's end-to-end serving scenario.

Builds the distributed learned index over a synthetic city-scale dataset
and serves batched spatial queries (point / range / circle / kNN / join)
through the unified adaptive executor, printing build + per-QuerySpec
latencies. This is the LiLIS deployment unit: the same executor runs
under the production mesh via --mesh host (queries replicated,
partitions sharded).

``python -m repro.launch.spatial --n 1000000 --partitions 64 --queries 256``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import (BACKENDS, CircleQuery, EngineConfig, Executor,
                        Knn, PointQuery, RangeCount, RangeQuery,
                        SpatialJoin, build_index, fit)
from repro.data import spatial as ds
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="taxi",
                    choices=list(ds.GENERATORS))
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--partitions", type=int, default=64)
    ap.add_argument("--partitioner", default="kdtree",
                    choices=["fixed", "adaptive", "quadtree", "kdtree",
                             "rtree"])
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--selectivity", type=float, default=1e-5)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--backend", choices=list(BACKENDS), default="auto",
                    help="kernel backend for the scan stages "
                         "(auto picks pallas on TPU)")
    ap.add_argument("--query-shard", action="store_true",
                    help="with --mesh host: split devices into a "
                         "(part, query) mesh and shard large query "
                         "batches over the query axis")
    ap.add_argument("--query-shard-threshold", type=int, default=None,
                    help="min batch size to query-shard (default: "
                         "EngineConfig default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"generating {args.n} {args.dataset} points ...")
    x, y = ds.make(args.dataset, args.n, seed=args.seed)

    t0 = time.perf_counter()
    part = fit(args.partitioner, x, y, args.partitions, seed=args.seed)
    t_part = time.perf_counter() - t0
    t0 = time.perf_counter()
    index = build_index(x, y, part)
    jax.block_until_ready(index.key)
    t_build = time.perf_counter() - t0
    sizes = index.size_bytes()
    print(f"partitioner fit {t_part*1e3:.0f} ms; index build "
          f"{t_build*1e3:.0f} ms; model {sizes['local_model']/1e3:.1f} KB"
          f" + global {sizes['global_index']/1e3:.1f} KB")

    cfg_kw = {"backend": args.backend}
    if args.query_shard_threshold is not None:
        cfg_kw["query_shard_threshold"] = args.query_shard_threshold
    cfg = EngineConfig(**cfg_kw)
    mesh = None
    query_axis = None
    if args.mesh == "host":
        n_dev = len(jax.devices())
        if args.query_shard and n_dev >= 2 and n_dev % 2 == 0:
            q_sz = 2
            # largest pow2 query axis that still leaves >= half the
            # devices for the partition axis
            while n_dev % (q_sz * 2) == 0 and q_sz * 2 <= n_dev // 2:
                q_sz *= 2
            mesh = make_host_mesh((n_dev // q_sz, q_sz),
                                  ("data", "query"))
            query_axis = "query"
        else:
            if args.query_shard:
                print(f"--query-shard needs an even device count >= 2 "
                      f"(have {n_dev}); using a partition-only mesh")
            mesh = make_host_mesh()
    ex = Executor(index, mesh=mesh, query_axis=query_axis, config=cfg)
    print(f"backend={ex.backend.name} mesh="
          f"{dict(mesh.shape) if mesh else None} query_axis={query_axis}")
    rng = np.random.default_rng(args.seed)
    q = args.queries

    ix = rng.integers(0, args.n, q)
    qx, qy = x[ix], y[ix]
    rects = ds.random_rects(q, args.selectivity, part.bounds,
                            seed=args.seed, centers=(x, y))
    polys, n_edges = ds.random_polygons(max(q // 8, 8), part.bounds,
                                        seed=args.seed)

    workload = [
        ("point", PointQuery(), (qx, qy), q),
        ("range_count", RangeCount(), (rects,), q),
        ("range", RangeQuery(), (rects,), q),
        ("circle", CircleQuery(), (qx, qy,
                                   np.full(q, 0.01, np.float32)), q),
        ("knn", Knn(k=args.k), (qx[:64], qy[:64]), 64),
        ("join", SpatialJoin(), (polys, n_edges), len(n_edges)),
    ]

    for name, spec, sargs, denom in workload:
        ex.run(spec, *sargs)      # compile + settle the sticky tier
        ex.run(spec, *sargs)      # compile the fused steady variant
        t0 = time.perf_counter()
        out = ex.run(spec, *sargs)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"{name:12s} {dt*1e3:9.2f} ms for batch "
              f"({dt/denom*1e6:8.1f} us/query)")
    st = ex.stats()
    print(f"executor: {st['cache_size']} cached executables, "
          f"{st['host_syncs']} host syncs total, sticky={st['sticky']}")


if __name__ == "__main__":
    main()
