import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks at first init).

"""Production-mesh dry-run for the SPATIAL engine (the paper's own
workload): lower + compile the distributed range / kNN / join programs
over a ~1B-point learned index (ShapeDtypeStructs, no allocation).

Partitions shard over ('data',) on the single pod and ('pod','data') on
the multi-pod mesh; the (tiny) global index and the query batch are
replicated — the same layout the CPU engine uses, scaled up.

  python -m repro.launch.dryrun_spatial --mesh both --out results/dryrun_spatial
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import keys as CK
from repro.core.build import LearnedSpatialIndex
from repro.core.executor import _shard_map_wrap
from repro.core.plan import EngineConfig
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh

# ~1.07B points: 4096 partitions x 262144 padded slots
P_TOTAL = 4096
N_PAD = 262144
M_PAD = 512
RADIX_BITS = 10
Q = 1024
PG = 256


def fake_index() -> LearnedSpatialIndex:
    """ShapeDtypeStruct-backed index (no data allocation)."""
    sd = jax.ShapeDtypeStruct
    return LearnedSpatialIndex(
        key=sd((P_TOTAL, N_PAD), jnp.uint32),
        x=sd((P_TOTAL, N_PAD), jnp.float32),
        y=sd((P_TOTAL, N_PAD), jnp.float32),
        vid=sd((P_TOTAL, N_PAD), jnp.int32),
        count=sd((P_TOTAL,), jnp.int32),
        knot_keys=sd((P_TOTAL, M_PAD), jnp.float32),
        knot_pos=sd((P_TOTAL, M_PAD), jnp.float32),
        n_knots=sd((P_TOTAL,), jnp.int32),
        radix_table=sd((P_TOTAL, (1 << RADIX_BITS) + 2), jnp.int32),
        radix_kmin=sd((P_TOTAL,), jnp.float32),
        radix_scale=sd((P_TOTAL,), jnp.float32),
        part_bounds=sd((P_TOTAL, 4), jnp.float32),
        eps=32, radix_bits=RADIX_BITS, probe=128,
        key_spec=CK.KeySpec(bounds=(0.0, 0.0, 1.0, 1.0)),
    )


def measured_shard_threshold(default: int | None = None) -> tuple:
    """The PR-2/3 sharding loop closed: prefer the MEASURED crossover
    recommendation (``python -m benchmarks.run --crossover`` records it
    in BENCH_quick.json) over the hardcoded EngineConfig default when
    sizing the production config."""
    if default is None:
        default = EngineConfig().query_shard_threshold
    path = os.environ.get("BENCH_QUICK_OUT", "BENCH_quick.json")
    try:
        with open(path) as f:
            rec = json.load(f)["crossover"]
        return int(rec["recommended_query_shard_threshold"]), "measured"
    except (OSError, ValueError, KeyError, TypeError):
        return int(default), "default"


def run(mesh_kind: str, out_dir: str, backend: str = "xla"):
    import repro.core.local_ops as E
    from repro.core.backends import resolve_backend

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    part_axis = ("pod", "data") if mesh_kind == "multi" else ("data",)
    index = fake_index()
    shard_threshold, shard_src = measured_shard_threshold()
    cfg = EngineConfig(part_chunk=8, range_cap=64, knn_cap=64,
                       range_cand=8, knn_cand=8, join_cap=128,
                       join_cand=8, backend=backend,
                       query_shard_threshold=shard_threshold)
    print(f"# query_shard_threshold={shard_threshold} ({shard_src}"
          " crossover)", flush=True)
    bk = resolve_backend(backend)

    # build the shardable parts dict as SDS (mirror _part_arrays)
    parts = {
        "keys_f": jax.ShapeDtypeStruct((P_TOTAL, N_PAD), jnp.float32),
        "x": index.x, "y": index.y, "vid": index.vid,
        "count": index.count,
        "knot_keys": index.knot_keys, "knot_pos": index.knot_pos,
        "n_knots": index.n_knots, "radix_table": index.radix_table,
        "radix_kmin": index.radix_kmin, "radix_scale": index.radix_scale,
    }
    bounds = index.part_bounds
    pspec = NamedSharding(mesh, P(part_axis))
    rspec = NamedSharding(mesh, P())
    parts_shard = jax.tree_util.tree_map(lambda _: pspec, parts)

    sd = jax.ShapeDtypeStruct
    cells = {}

    def lower_one(name, local_fn, qargs, qshapes):
        axes = part_axis
        in_specs = (P(axes),) + (P(),) * (local_fn.n_query_args + 1)
        from functools import partial as fpartial
        wrapped = _shard_map_wrap(fpartial(local_fn, axis=axes), mesh,
                                  in_specs, P())
        t0 = time.time()
        lowered = jax.jit(wrapped, in_shardings=(
            parts_shard, rspec) + (rspec,) * len(qshapes)).lower(
            parts, bounds, *qshapes)
        compiled = lowered.compile()
        rep = hlo.analyze_compiled(compiled, chips, model_flops=0.0)
        rep.update({"arch": "lilis-spatial", "shape": name,
                    "mesh": mesh_kind, "chips": chips,
                    "compile_s": round(time.time() - t0, 1),
                    "points": P_TOTAL * N_PAD, "queries": qargs,
                    "query_shard_threshold": shard_threshold,
                    "query_shard_threshold_src": shard_src})
        path = os.path.join(out_dir, f"lilis-spatial__{name}__"
                                     f"{mesh_kind}.json")
        hlo.dump(rep, path)
        r = rep["roofline"]
        print(f"OK   spatial/{name}/{mesh_kind}: "
              f"bottleneck={r['bottleneck']} tc={r['t_compute_s']:.2e} "
              f"tm={r['t_memory_s']:.2e} tl={r['t_collective_s']:.2e}",
              flush=True)
        cells[name] = rep

    # 1) baseline range: full-refine mask path (partition-centric scan)
    lower_one("range_mask", E._RangeCountLocal(index, cfg, bk), Q,
              (sd((Q, 4), jnp.float32), sd((Q,), jnp.float32),
               sd((Q,), jnp.float32)))
    # 2) optimized range: query-centric windowed + z-split
    lower_one("range_window",
              E._RangeWindowLocal(index, cfg, bk, cfg.range_cap,
                                  cfg.range_cand), Q,
              (sd((Q, 4), jnp.float32), sd((Q,), jnp.float32),
               sd((Q,), jnp.float32)))
    # 3) kNN pruned (k=10)
    lower_one("knn10",
              E._KnnPrunedLocal(index, cfg, bk, 10, index.key_spec,
                                cfg.knn_cand, cfg.knn_cap), Q,
              (sd((Q,), jnp.float32), sd((Q,), jnp.float32),
               sd((Q,), jnp.float32)))
    # 4) join (256 polygons x 16 edges)
    lower_one("join",
              E._JoinLocal(index, cfg, bk, cfg.join_cap, cfg.join_cand),
              PG,
              (sd((PG, 16, 2), jnp.float32), sd((PG,), jnp.int32),
               sd((PG, 6), jnp.float32)))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--backend", default="xla",
                    choices=["auto", "xla", "pallas"],
                    help="kernel backend to lower (pallas lowers the "
                         "real kernels when run on TPU)")
    ap.add_argument("--out", default="results/dryrun_spatial")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mk in (["single", "multi"] if args.mesh == "both"
               else [args.mesh]):
        try:
            run(mk, args.out, backend=args.backend)
        except Exception:
            failures += 1
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
