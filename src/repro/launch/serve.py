"""Serving drivers.

LM serving (prefill + batched greedy decode):

``python -m repro.launch.serve --arch qwen2.5-3b --smoke --tokens 32``

Spatial query serving (mixed QuerySpec workload through the unified
adaptive executor — the paper's decision-analysis scenario):

``python -m repro.launch.serve --spatial --n 200000 --rounds 8``

Add ``--scheduler`` to serve the same workload through the streaming
front door (serve/scheduler.py, DESIGN.md §12): concurrent client
threads submitting single-query requests plus an insert stream, a
worker thread coalescing them into micro-batches, maintenance at idle.
"""
from __future__ import annotations

import argparse
import time

import jax


def run_lm(args):
    from repro.configs import get_config
    from repro.data.tokens import make_batch
    from repro.models import build_model
    from repro.serve import generate

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len, seed=1)
    batch.pop("labels", None)
    batch.pop("mask", None)
    t0 = time.perf_counter()
    out = generate(model, params, batch, steps=args.tokens)
    dt = time.perf_counter() - t0
    n = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s)")
    print(out[:, :16])


def run_spatial_scheduler(args):
    """Concurrent traffic through the scheduler front door."""
    import threading

    import numpy as np

    from repro.core import (CircleQuery, EngineConfig, InsertBatch, Knn,
                            PointQuery, RangeCount, build_index, fit)
    from repro.data import spatial as ds
    from repro.serve import SpatialServeSession

    print(f"building index over {args.n} points ...")
    x, y = ds.make("taxi", args.n, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)
    session = SpatialServeSession(
        build_index(x, y, part),
        config=EngineConfig(backend=args.backend))
    print(f"backend={session.stats()['backend']}")

    rng = np.random.default_rng(1)
    n_req = args.rounds * args.batch
    rects = ds.random_rects(n_req, 1e-5, part.bounds, seed=2,
                            centers=(x, y))
    reqs = []
    for i in range(n_req):
        j = int(rng.integers(0, args.n))
        kind = i % 4
        if kind == 0:
            reqs.append((PointQuery(), x[j:j + 1], y[j:j + 1]))
        elif kind == 1:
            reqs.append((RangeCount(), rects[i:i + 1]))
        elif kind == 2:
            reqs.append((Knn(k=10), x[j:j + 1], y[j:j + 1]))
        else:
            reqs.append((CircleQuery(), x[j:j + 1], y[j:j + 1],
                         np.full(1, 0.02, np.float32)))
    print("warmup (compilation + sticky tiers settle off the hot path)")
    session.warmup([(s, *a) for s, *a in reqs[:4]])

    lat_us = []
    lock = threading.Lock()
    with session.scheduler() as sched:
        bx = (x[:args.batch] + 1e-4).astype(np.float32)
        by = (y[:args.batch] + 1e-4).astype(np.float32)
        sched.submit(InsertBatch(), bx, by).result(120.0)  # prewarm

        def client(k, nc=8):
            mine = []
            for i in range(k, len(reqs), nc):
                spec, *a = reqs[i]
                t0 = time.perf_counter()
                sched.submit(spec, *a).result(120.0)
                mine.append((time.perf_counter() - t0) * 1e6)
            with lock:
                lat_us.extend(mine)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(8)]
        ing = threading.Thread(
            target=lambda: sched.submit(InsertBatch(), bx, by)
            .result(120.0))
        ing.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        ing.join()
        wall = time.perf_counter() - t0
        sched.drain()
        st = sched.stats()
    lat = np.asarray(lat_us)
    print(f"{len(reqs)} requests from 8 clients in {wall:.2f}s "
          f"({len(reqs) / wall:.0f} req/s)")
    print(f"p50 {np.percentile(lat, 50):,.0f} us   "
          f"p99 {np.percentile(lat, 99):,.0f} us   "
          f"mean batch {st['mean_batch']}   max {st['max_batch']}   "
          f"maintain {st['maintain_runs']} runs "
          f"({st['maintain_busy']} busy)")


def run_spatial(args):
    import numpy as np

    from repro.core import (CircleQuery, EngineConfig, Knn, PointQuery,
                            RangeCount, RangeQuery, SpatialJoin,
                            build_index, fit)
    from repro.data import spatial as ds
    from repro.serve import SpatialServeSession

    print(f"building index over {args.n} points ...")
    x, y = ds.make("taxi", args.n, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)
    session = SpatialServeSession(
        build_index(x, y, part),
        config=EngineConfig(backend=args.backend))
    print(f"backend={session.stats()['backend']}")

    rng = np.random.default_rng(1)
    q = args.batch

    def make_round(seed):
        ix = rng.integers(0, args.n, q)
        rects = ds.random_rects(q, 1e-5, part.bounds, seed=seed,
                                centers=(x, y))
        polys, ne = ds.random_polygons(max(q // 8, 4), part.bounds,
                                       seed=seed)
        return [(PointQuery(), x[ix], y[ix]),
                (RangeCount(), rects),
                (RangeQuery(), rects),
                (CircleQuery(), x[ix], y[ix],
                 np.full(q, 0.02, np.float32)),
                (Knn(k=10), x[ix], y[ix]),
                (SpatialJoin(), polys, ne)]

    print("warmup (compilation + sticky tiers settle off the hot path)")
    session.warmup(make_round(0))
    syncs0 = session.stats()["host_syncs"]

    for rnd in range(args.rounds):
        reqs = make_round(rnd + 1)
        t0 = time.perf_counter()
        out = session.submit_batch(reqs)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        st = session.stats()
        print(f"round {rnd}: {len(reqs)} mixed specs in {dt*1e3:7.2f} ms "
              f"(host_syncs +{st['host_syncs'] - syncs0}, "
              f"cache {st['cache_size']} executables)")
        moved = session.maintain()       # re-tune OFF the hot path
        if moved:
            print(f"  maintain: escalated {moved}")
        syncs0 = session.stats()["host_syncs"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spatial", action="store_true",
                    help="serve mixed spatial QuerySpecs instead of an LM")
    ap.add_argument("--scheduler", action="store_true",
                    help="with --spatial: serve through the streaming "
                         "scheduler (concurrent clients, coalesced "
                         "micro-batches, idle maintenance)")
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="batch size (default: 4 for LM, 64 for "
                         "--spatial)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="spatial kernel backend (auto: pallas on TPU)")
    args = ap.parse_args()
    if args.spatial:
        if args.batch is None:
            args.batch = 64
        if args.scheduler:
            run_spatial_scheduler(args)
        else:
            run_spatial(args)
    else:
        if not args.arch:
            ap.error("--arch is required unless --spatial")
        if args.batch is None:
            args.batch = 4
        run_lm(args)


if __name__ == "__main__":
    main()
