"""Serving driver: prefill + batched greedy decode.

``python -m repro.launch.serve --arch qwen2.5-3b --smoke --tokens 32``
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.tokens import make_batch
from repro.models import build_model
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len, seed=1)
    batch.pop("labels", None)
    batch.pop("mask", None)
    t0 = time.perf_counter()
    out = generate(model, params, batch, steps=args.tokens)
    dt = time.perf_counter() - t0
    n = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
