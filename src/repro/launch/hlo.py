"""Compiled-HLO analysis: collective bytes + 3-term roofline.

collective_bytes is NOT in cost_analysis — we parse the post-SPMD
optimized HLO (compiled.as_text()) and sum result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighted by the ring-transfer factor:

    all-reduce        2x  (reduce-scatter + all-gather phases)
    all-gather        1x  (each chip receives ~result bytes)
    reduce-scatter    1x
    all-to-all        1x
    collective-permute 1x

Shapes in the optimized HLO are PER-DEVICE, so summed bytes are already
per-chip link traffic.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|"
                       r"u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind byte totals (+ weighted link bytes) from optimized HLO.

    `-done` ops carry the same tuple shape as `-start`; count starts only.
    """
    out = {k: 0.0 for k in _COLL_FACTOR}
    counts = {k: 0 for k in _COLL_FACTOR}
    weighted = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        b = _shape_bytes(shape_text)
        out[kind] += b
        counts[kind] += 1
        weighted += b * _COLL_FACTOR[kind]
    return {"bytes_by_kind": out, "counts": counts,
            "weighted_link_bytes": weighted}


@dataclasses.dataclass
class Roofline:
    """All inputs are PER-CHIP quantities (post-SPMD HLO shapes are
    per-device; equivalently HLO_total/(chips*peak) per the assignment
    formula since SPMD programs are uniform across chips)."""

    flops: float
    hbm_bytes: float
    link_bytes: float
    chips: int
    model_flops: float = 0.0   # useful 6ND work per chip

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> float:
        if self.flops <= 0:
            return 0.0
        return self.model_flops / self.flops

    @property
    def roofline_frac(self) -> float:
        """(useful work time at peak) / (bound step time)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        if bound <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / bound

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "link_bytes": self.link_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def analyze_compiled(compiled, chips: int, model_flops: float = 0.0):
    """Extract roofline terms from a jax compiled object.

    Primary numbers come from the trip-count-aware HLO walker
    (hlo_walk.py) because XLA's HloCostAnalysis counts while-loop bodies
    once (scan-heavy programs underreport by orders of magnitude —
    verified in EXPERIMENTS.md §Dry-run). Post-SPMD shapes are
    per-device, so walker totals are PER-CHIP; `model_flops` (6ND) is the
    cross-chip total and is divided by `chips` for the useful-work
    comparison.
    """
    from repro.launch import hlo_walk
    text = compiled.as_text()
    walk = hlo_walk.total_cost(text)
    cost = hlo_walk.xla_cost_analysis(compiled) or {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception:
        pass
    roof = Roofline(flops=walk["flops"], hbm_bytes=walk["hbm_bytes"],
                    link_bytes=walk["weighted_link_bytes"], chips=chips,
                    model_flops=model_flops / max(chips, 1))
    return {
        "roofline": roof.to_dict(),
        "collectives": {"bytes_by_kind": walk["coll_bytes"],
                        "counts": walk["coll_counts"],
                        "weighted_link_bytes":
                            walk["weighted_link_bytes"]},
        "memory_analysis": mem,
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies once; see hlo_walk.py",
        },
    }


def dump(obj, path: str):
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
