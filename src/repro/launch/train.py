"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs the fault-tolerant loop (auto-resume, async checkpoints, straggler
watchdog) on whatever devices exist — smoke configs train a ~100k-param
model on CPU; full configs expect the production mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.train import TrainLoopConfig, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "host", "pod", "multipod"],
                    default="none")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault injection (testing)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh == "pod":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)

    step = make_train_step(model, mesh=mesh, n_micro=args.micro,
                           peak_lr=args.lr, total_steps=args.steps)
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=0)
    loop_cfg = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every,
                               crash_at_step=args.crash_at)
    params, opt, hist = train_loop(model, step, pipe, loop_cfg,
                                   rng=jax.random.PRNGKey(0))
    print(f"final loss: {hist['loss'][-1]:.4f}  "
          f"stragglers: {hist['stragglers']}")


if __name__ == "__main__":
    main()
