"""Collective attribution: which source ops own the collective bytes.

Used by the §Perf hillclimb loop: folds trip-count multipliers through
the call graph (like hlo_walk) but keeps per-op attribution via the
op_name metadata XLA preserves into the optimized HLO.
"""
from __future__ import annotations

import re

from repro.launch import hlo_walk

COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")


def attribute(text: str, top: int = 12):
    costs = hlo_walk.parse_costs(text)
    comps = hlo_walk.split_computations(text)
    entry = hlo_walk._entry_name(text)
    mult = {entry: 1.0}
    q = [entry]
    while q:
        nm = q.pop()
        cc = costs.get(nm)
        if not cc:
            continue
        for sub, m, _ in cc.subcalls:
            mult[sub] = mult.get(sub, 0.0) + mult[nm] * m
            q.append(sub)
    rows = {}
    for nm, lines in comps.items():
        mm = mult.get(nm, 0.0)
        if mm == 0:
            continue
        for ln in lines:
            m = hlo_walk.OP_RE.match(ln)
            if not m:
                continue
            op = m.group(3).replace("-start", "")
            if op not in COLL:
                continue
            b = hlo_walk._shapes_bytes(m.group(2))
            meta = re.search(r'op_name="([^"]*)"', ln)
            key = (op, m.group(2)[:48],
                   (meta.group(1)[-60:] if meta else "?"))
            rows[key] = rows.get(key, 0.0) + b * mm
    out = sorted(rows.items(), key=lambda kv: -kv[1])[:top]
    return [{"op": k[0], "shape": k[1], "src": k[2], "gb": v / 1e9}
            for k, v in out]
