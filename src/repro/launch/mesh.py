"""Production mesh definitions.

Functions (never module-level constants) so importing this module never
touches jax device state — required by the dry-run's device-count
override ordering.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(dry-run) or on real hardware")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh(shape=None, axes=None) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
        axes = ("data", "model")
    dev = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(dev, axes or ("data", "model"))
