import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks at first init).
# (No `from __future__` here for the same reason: these two lines must
# stay the first statements of the module.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no mismatched collectives),
  * the per-device program fits (memory_analysis),
  * and yields FLOPs / bytes / collective-bytes for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all --mesh both --out results/
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.data.tokens import input_specs
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.sharding import (MeshRules, batch_specs, cache_specs,
                            param_specs, use_mesh)
from repro.train.optimizer import AdamWState, adamw_init
from repro.train.step import make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k runs only for sub-quadratic archs (see DESIGN.md
# §Arch-applicability); encoder-only archs would skip decode shapes but
# none of the assigned archs is encoder-only. (Both id spellings.)
LONG_OK = {"rwkv6-3b", "hymba-1.5b", "hymba-1-5b", "gemma3-4b"}


def arch_cells(arch: str):
    for shape in SHAPES:
        if shape == "long_500k" and arch not in LONG_OK:
            continue
        yield shape


def _micro_for(cfg, batch_local: int, seq: int) -> int:
    """Microbatch count keeping rematerialized layer-boundary activations
    under ~2 GB/device: L * (B/micro) * S * d * 2B <= 2e9."""
    per = cfg.n_layers * batch_local * seq * cfg.d_model * 2
    n = 1
    while per / n > 2e9 and n < batch_local:
        n *= 2
    return n


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    """Env-controlled perf variants (hillclimb; see EXPERIMENTS.md §Perf):
      REPRO_BF16_W=1   cast weights to bf16 once per step (train/prefill)
      REPRO_REMAT=x    remat policy name (none|dots)
    """
    bf16_w = os.environ.get("REPRO_BF16_W") == "1"
    remat_policy = os.environ.get("REPRO_REMAT")
    if remat_policy:
        from repro.models import transformer as T
        T.set_remat_policy(remat_policy)
    cfg = get_config(arch)
    model = build_model(cfg)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    rules = MeshRules()
    t0 = time.time()

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(mesh, rules, params_sds)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if spec["kind"] == "train":
        batch_sds = input_specs(cfg, spec["batch"], spec["seq"])
        bspecs = batch_specs(mesh, rules, batch_sds)
        dp = chips // mesh.shape["model"]
        n_micro = _micro_for(cfg, spec["batch"] // dp, spec["seq"])
        step = make_train_step(model, mesh=mesh, rules=rules,
                               n_micro=n_micro, donate=False,
                               bf16_weights=bf16_w).raw
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        ospecs = AdamWState(
            step=NamedSharding(mesh, P()),
            m=param_specs(mesh, rules, opt_sds.m),
            v=param_specs(mesh, rules, opt_sds.v))
        lowered = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs)
                          ).lower(params_sds, opt_sds, batch_sds)
        # 6ND + attention flops (2*6*B*S^2*d per layer lower bound skipped)
        tokens = spec["batch"] * spec["seq"]
        model_flops = 6.0 * n_active * tokens
        extra = dict(n_micro=n_micro)
    elif spec["kind"] == "prefill":
        batch_sds = input_specs(cfg, spec["batch"], spec["seq"])
        bspecs = batch_specs(mesh, rules, batch_sds)

        def prefill(p, b):
            with use_mesh(mesh, rules):
                if bf16_w:
                    p = jax.tree_util.tree_map(
                        lambda w: w.astype(jnp.bfloat16)
                        if w.dtype == jnp.float32 and w.ndim >= 2 else w,
                        p)
                return model.prefill(p, b, max_len=spec["seq"])

        lowered = jax.jit(prefill, in_shardings=(pspecs, bspecs)).lower(
            params_sds, batch_sds)
        tokens = spec["batch"] * spec["seq"]
        model_flops = 2.0 * n_active * tokens
        extra = {}
    else:  # decode
        b, s = spec["batch"], spec["seq"]
        if cfg.family == "rwkv6":
            cache_sds = jax.eval_shape(lambda: model.init_state(b))
        elif cfg.family == "encdec":
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(b, max(s // 8, 1024), s))
        else:
            cache_sds = jax.eval_shape(lambda: model.init_cache(b, s))
        cspecs = cache_specs(mesh, rules, cache_sds)
        from repro.sharding.api import spec_for
        tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_spec = NamedSharding(
            mesh, spec_for(mesh, rules, (b, 1), ("batch", None)))
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def decode(p, c, t, pos):
            with use_mesh(mesh, rules):
                return model.decode_step(p, c, t, pos)

        lowered = jax.jit(decode, in_shardings=(
            pspecs, cspecs, tok_spec, NamedSharding(mesh, P()))).lower(
            params_sds, cache_sds, tok_sds, pos_sds)
        model_flops = 2.0 * n_active * b
        extra = {}

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    report = hlo.analyze_compiled(compiled, chips,
                                  model_flops=model_flops)
    report.update({
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips, "params": n_params, "active_params": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **extra,
    })
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        shapes = ([args.shape] if args.shape else list(arch_cells(arch)))
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_OK:
                print(f"SKIP {arch} {shape} (full-attention arch; see "
                      f"DESIGN.md)")
                continue
            for mk in meshes:
                tag = f"{arch}__{shape}__{mk}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"done {tag} (cached)")
                    continue
                try:
                    rep = run_cell(arch, shape, mk)
                    hlo.dump(rep, path)
                    r = rep["roofline"]
                    print(f"OK   {tag}: bottleneck={r['bottleneck']} "
                          f"tc={r['t_compute_s']:.2e} "
                          f"tm={r['t_memory_s']:.2e} "
                          f"tl={r['t_collective_s']:.2e} "
                          f"compile={rep['compile_s']}s", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
    print(f"dry-run complete, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
