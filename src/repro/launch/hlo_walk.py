"""Trip-count-aware cost analysis over optimized HLO text.

XLA's built-in HloCostAnalysis counts while-loop bodies ONCE (verified:
a lax.scan of 10 matmuls reports the flops of 1). Every layer stack,
microbatch accumulation, and flash-attention chunk loop in this codebase
is a scan, so compiled.cost_analysis() underreports by orders of
magnitude. This walker fixes that:

  * splits the HLO module into computations,
  * per computation, sums dot FLOPs (2 * prod(result) * contraction),
    memory-traffic bytes (operands + results of dot/fusion/copy/dus/
    gather/scatter/convert ops), and collective bytes by kind,
  * recovers while-loop trip counts from the loop condition
    (`compare(iv, constant), direction=LT` pattern emitted by scan /
    fori_loop), and
  * folds costs up the call graph (fusion/call/while) with trip-count
    multipliers.

Per-device semantics: shapes in post-SPMD optimized HLO are per-device,
so totals are per-chip.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|"
    r"s4|u4|pred|c64|c128)\[([\d,]*)\]")

COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')

OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\(")

WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
CMP_DIR_RE = re.compile(r"direction=(LT|LE|GT|GE|NE|EQ)")
DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

MEM_OPS = {"dot", "fusion", "copy", "dynamic-update-slice",
           "dynamic-slice", "gather", "scatter", "convert", "transpose",
           "broadcast", "reduce", "convolution", "select-and-scatter",
           "concatenate", "slice", "pad", "reverse", "sort", "iota",
           "add", "multiply", "subtract", "divide", "exponential",
           "select", "compare", "rsqrt", "tanh", "maximum", "minimum"}

COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute", "all-reduce-start", "all-gather-start",
            "collective-permute-start"}

COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
               "reduce-scatter": 1.0, "all-to-all": 1.0,
               "collective-permute": 1.0}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> Tuple[str, List[int]]:
    m = SHAPE_RE.search(text)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def split_computations(text: str) -> Dict[str, List[str]]:
    """Computation headers end with '{' and contain '->' (possibly with
    nested parens in the signature)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        ls = line.strip()
        if cur is None:
            if ls.endswith("{") and "->" in ls:
                m = COMP_HEADER_RE.match(ls)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if ls == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _entry_name(text: str) -> str:
    for line in text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            m = COMP_HEADER_RE.match(ls)
            if m:
                return m.group(1)
    return ""


OPERAND_RE = re.compile(r"\(%?([\w\.\-]+)(?:,\s*%?([\w\.\-]+))*")
ARGS_RE = re.compile(r"%([\w\.\-]+)")


def _operand_names(line: str, op: str):
    """Names inside the op's argument parens."""
    _, _, post = line.partition(f" {op}(")
    depth = 1
    args = []
    for i, ch in enumerate(post):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = ARGS_RE.findall(post[:i])
                break
    return args


def _dot_flops(line: str, symtab: Dict[str, Tuple[str, List[int]]]
               ) -> float:
    """2 * prod(result dims) * prod(contracting dims of lhs)."""
    pre, _, post = line.partition(" dot(")
    _, rdims = _first_shape_dims(pre.split("=", 1)[1] if "=" in pre
                                 else pre)
    m = DOT_DIMS_RE.search(post)
    ops = _operand_names(line, "dot")
    if not m or not ops or ops[0] not in symtab:
        return 0.0
    lhs_dims = symtab[ops[0]][1]
    contracting = [int(i) for i in m.group(1).split(",") if i]
    csize = 1
    for i in contracting:
        if i < len(lhs_dims):
            csize *= lhs_dims[i]
    rsize = 1
    for d in rdims:
        rsize *= d
    return 2.0 * rsize * csize


class CompCost:
    __slots__ = ("flops", "bytes", "coll", "coll_counts", "subcalls")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {k: 0.0 for k in COLL_FACTOR}
        self.coll_counts = {k: 0 for k in COLL_FACTOR}
        # (comp, multiplier, count_bytes) — fusion-internal computations
        # do NOT touch HBM, so their bytes are excluded from the fold.
        self.subcalls: List[Tuple[str, float, bool]] = []


def _trip_count(cond_lines: List[str]) -> float:
    """Extract trip count from a scan/fori while-condition computation."""
    consts = []
    direction = None
    for ln in cond_lines:
        for c in CONST_RE.findall(ln):
            consts.append(int(c))
        m = CMP_DIR_RE.search(ln)
        if m:
            direction = m.group(1)
    if not consts:
        return 1.0
    n = max(consts)
    if direction == "LE":
        n += 1
    return float(max(n, 1))


# HBM-traffic model per op kind (post-fusion HLO; instruction
# granularity ~= materialization points). The tricky cases:
#   * dynamic-slice / gather read ~result bytes, NOT their (often
#     layer-stacked, loop-carried) full operand;
#   * dynamic-update-slice is aliased in-place by XLA inside while
#     bodies: traffic ~= 2x the UPDATE slice, not the full buffer;
#   * kLoop fusions stream: reads are capped at ~result size per
#     operand (a fusion that slices a stacked weight reads one layer);
#   * kInput (reduction) fusions genuinely read their full operands.
ELEMWISE_2X = {
    "copy", "convert", "transpose", "reverse", "pad", "slice",
    "concatenate", "broadcast", "iota", "rng", "sort", "dynamic-slice",
    "gather", "exponential", "add", "multiply", "subtract", "divide",
    "select", "compare", "rsqrt", "tanh", "maximum", "minimum", "clamp",
    "negate", "logistic", "power", "sqrt", "sign", "and", "or", "xor",
    "not", "scatter", "reduce-window", "select-and-scatter", "map",
}
READ_ALL_OPS = {"reduce", "convolution", "custom-call", "cholesky",
                "triangular-solve"}
FUSION_KIND_RE = re.compile(r"kind=k(Loop|Input|Output|Custom)")


def _dims_bytes(entry) -> int:
    dt, dims = entry
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _root_dus_update_bytes(comp_lines):
    """If the computation's ROOT is a dynamic-update-slice, bytes of its
    update operand; else None."""
    symtab = {}
    root = None
    for ln in comp_lines:
        m = OP_RE.match(ln)
        if not m:
            continue
        symtab[m.group(1)] = _first_shape_dims(m.group(2))
        if "ROOT" in ln and m.group(3) == "dynamic-update-slice":
            root = ln
    if root is None:
        return None
    ops = _operand_names(root, "dynamic-update-slice")
    if len(ops) >= 2 and ops[1] in symtab:
        return _dims_bytes(symtab[ops[1]])
    return None


def parse_costs(text: str) -> Dict[str, CompCost]:
    comps = split_computations(text)
    costs: Dict[str, CompCost] = {}
    for name, lines in comps.items():
        cc = CompCost()
        # pass 1: symbol table (instruction name -> result dtype/dims)
        symtab: Dict[str, Tuple[str, List[int]]] = {}
        for ln in lines:
            m = OP_RE.match(ln)
            if not m:
                continue
            symtab[m.group(1)] = _first_shape_dims(m.group(2))

        def operand_bytes(ln, op, cap=None):
            total = 0
            for nm in _operand_names(ln, op):
                if nm in symtab:
                    b = _dims_bytes(symtab[nm])
                    if cap is not None:
                        b = min(b, cap)
                    total += b
            return total

        # pass 2: costs
        for ln in lines:
            m = OP_RE.match(ln)
            if not m:
                continue
            result_text, op = m.group(2), m.group(3)
            rbytes = _shapes_bytes(result_text)
            if op == "dot":
                cc.flops += _dot_flops(ln, symtab)
                cc.bytes += rbytes + operand_bytes(ln, op)
            elif op in COLL_OPS:
                base = op.replace("-start", "")
                cc.coll[base] += rbytes
                cc.coll_counts[base] += 1
                cc.bytes += 2 * rbytes
            elif op == "fusion":
                cm = CALLS_RE.search(ln)
                km = FUSION_KIND_RE.search(ln)
                kind = km.group(1) if km else "Loop"
                if cm:
                    cc.subcalls.append((cm.group(1), 1.0, False))
                    dus = _root_dus_update_bytes(comps.get(cm.group(1),
                                                           []))
                else:
                    dus = None
                if dus is not None:
                    cc.bytes += 2 * dus       # in-place cache update
                elif kind == "Input":
                    cc.bytes += rbytes + operand_bytes(ln, op)
                else:  # Loop / Output: stream, cap slicing reads
                    cc.bytes += 2 * rbytes + operand_bytes(
                        ln, op, cap=rbytes)
            elif op == "dynamic-update-slice":
                ops = _operand_names(ln, op)
                upd = (_dims_bytes(symtab[ops[1]])
                       if len(ops) >= 2 and ops[1] in symtab else rbytes)
                cc.bytes += 2 * upd
            elif op in ELEMWISE_2X:
                cc.bytes += 2 * rbytes
            elif op in READ_ALL_OPS:
                cc.bytes += rbytes + operand_bytes(ln, op)
            if op in ("call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "select-and-scatter"):
                cm = TO_APPLY_RE.search(ln) or CALLS_RE.search(ln)
                if cm:
                    cc.subcalls.append((cm.group(1), 1.0, False))
            elif op == "while":
                wm = WHILE_RE.search(ln)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    tm = TRIP_RE.search(ln)   # XLA's own trip analysis
                    if tm:
                        trips = float(tm.group(1))
                    else:
                        trips = _trip_count(comps.get(cond, []))
                    cc.subcalls.append((body, trips, True))
                    cc.subcalls.append((cond, trips, True))
            elif op == "conditional":
                for cm in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"true_computation=%?([\w\.\-]+)|"
                        r"false_computation=%?([\w\.\-]+))", ln):
                    grp = cm.group(1)
                    if grp:
                        for b in grp.split(","):
                            cc.subcalls.append(
                                (b.strip().lstrip("%"), 1.0, True))
                    else:
                        cc.subcalls.append(
                            ((cm.group(2) or cm.group(3)), 1.0, True))
        costs[name] = cc
    return costs


def total_cost(text: str) -> dict:
    """Fold per-computation costs through the call graph."""
    costs = parse_costs(text)
    entry = _entry_name(text)
    memo: Dict[str, Tuple[float, float, dict, dict]] = {}

    def fold(name: str, depth=0):
        if name in memo:
            return memo[name]
        cc = costs.get(name)
        if cc is None or depth > 64:
            return (0.0, 0.0, {k: 0.0 for k in COLL_FACTOR},
                    {k: 0 for k in COLL_FACTOR})
        fl, by = cc.flops, cc.bytes
        co = dict(cc.coll)
        cn = dict(cc.coll_counts)
        for sub, mult, count_bytes in cc.subcalls:
            sf, sb, sc, scn = fold(sub, depth + 1)
            fl += sf * mult
            if count_bytes:
                by += sb * mult
            for k in co:
                co[k] += sc[k] * mult
                cn[k] += int(scn[k] * mult)
        memo[name] = (fl, by, co, cn)
        return memo[name]

    fl, by, co, cn = fold(entry)
    weighted = sum(co[k] * COLL_FACTOR[k] for k in co)
    return {"flops": fl, "hbm_bytes": by, "coll_bytes": co,
            "coll_counts": cn, "weighted_link_bytes": weighted,
            "entry": entry}


def xla_cost_analysis(compiled) -> dict:
    """XLA's own HloCostAnalysis for a compiled executable, normalized.

    jax's ``Compiled.cost_analysis()`` has returned a one-element list
    of dicts on older versions and a bare dict on newer ones; callers
    comparing against this walker (which exists because XLA undercounts
    loop bodies) shouldn't care which jax they run under.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
