"""LiLIS core: the paper's primary contribution in JAX.

Public API:
  KeySpec, make_keys           — 1-D key projection (morton / axis)
  build_spline, build_radix    — error-bounded spline + float radix table
  Partitioner, fit             — spatial-aware partitioners (5 strategies)
  build_index                  — distributed index build pipeline
  LearnedSpatialIndex          — the index pytree
  QuerySpec family             — declarative query plans (core/plan.py):
    PointQuery, RangeCount, RangeQuery, CircleQuery, Knn, SpatialJoin
  UpdateSpec family            — declarative mutations (DESIGN.md §11):
    InsertBatch, DeleteBatch, Refit
  refit_partitions             — per-partition compaction + spline re-fit
  Executor                     — unified adaptive executor: run(spec, ...)
  SpatialEngine                — method-per-query facade over Executor
"""
from repro.core.keys import KeySpec, make_keys  # noqa: F401
from repro.core.spline import build_spline, spline_predict  # noqa: F401
from repro.core.radix import build_radix, radix_locate  # noqa: F401
from repro.core.partitioner import Partitioner, fit, STRATEGIES  # noqa: F401
from repro.core.build import LearnedSpatialIndex, build_index  # noqa: F401
from repro.core.plan import (  # noqa: F401
    ALL_SPEC_TYPES, ALL_UPDATE_TYPES, CircleQuery, DeleteBatch,
    EngineConfig, InsertBatch, Knn, PointQuery, QuerySpec, RangeCount,
    RangeQuery, Refit, SpatialJoin, UpdateSpec, exec_key)
from repro.core.mutate import (  # noqa: F401
    delta_occupancy, refit_partitions, verify_eps, with_delta_capacity)
from repro.core.backends import (  # noqa: F401
    BACKENDS, PallasBackend, XlaBackend, resolve_backend)
from repro.core.executor import Executor  # noqa: F401
from repro.core.engine import SpatialEngine  # noqa: F401
