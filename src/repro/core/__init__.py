"""LiLIS core: the paper's primary contribution in JAX.

Public API:
  KeySpec, make_keys           — 1-D key projection (morton / axis)
  build_spline, build_radix    — error-bounded spline + float radix table
  Partitioner, fit             — spatial-aware partitioners (5 strategies)
  build_index                  — distributed index build pipeline
  LearnedSpatialIndex          — the index pytree
  SpatialEngine                — distributed two-phase query engine
"""
from repro.core.keys import KeySpec, make_keys  # noqa: F401
from repro.core.spline import build_spline, spline_predict  # noqa: F401
from repro.core.radix import build_radix, radix_locate  # noqa: F401
from repro.core.partitioner import Partitioner, fit, STRATEGIES  # noqa: F401
from repro.core.build import LearnedSpatialIndex, build_index  # noqa: F401
from repro.core.engine import SpatialEngine, EngineConfig  # noqa: F401
