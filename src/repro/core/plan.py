"""Declarative query plans: frozen QuerySpec dataclasses (DESIGN.md §9).

A QuerySpec describes WHAT to compute — query type plus the static
parameters that shape its compiled program (k, materialization, an
optional user cap). It deliberately carries no data and no tuning
state: query arrays are passed to ``Executor.run(spec, *args)`` and the
adaptive ``(cap, cand)`` window state is owned by the executor, keyed
by ``spec.sticky_key()`` so every instance of an equal spec shares one
compiled-executable cache line and one sticky entry.

Two key kinds:

  ``plan_key()``    canonical identity of the compiled program family
                    (query type + static params). Equal specs — however
                    constructed — produce equal plan keys.
  ``sticky_key()``  identity of the adaptive-cap state. Coarser than
                    plan_key: e.g. every RangeQuery shares "range"
                    sticky state regardless of a user cap override.

New query types are added here as one more frozen dataclass plus one
local kernel — not another copy of the engine's retry loop (that lives
once, in ``executor.Executor``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Initial window sizes for the adaptive executor (DESIGN.md §7)
    plus the kernel-backend / query-sharding knobs (DESIGN.md §10)."""
    part_chunk: int = 8          # partitions processed per lax.map step
    range_cap: int = 64          # windowed-range candidate cap/partition
    knn_cap: int = 64            # windowed kNN gather cap per partition
    knn_max_rounds: int = 24     # radius doublings (covers any dataset)
    join_cap: int = 128          # windowed join candidate cap/partition
    range_cand: int = 8          # candidate partitions per range query
    knn_cand: int = 8            # candidate partitions per kNN query
    join_cand: int = 8           # candidate partitions per polygon
    circle_cap: int = 64         # windowed circle candidate cap/partition
    circle_cand: int = 8         # candidate partitions per circle query
    backend: str = "auto"        # kernel backend: auto | xla | pallas
    query_shard_threshold: int = 1024   # min batch to shard query axis
    demote_after: int = 3        # consecutive clean maintain() checks
                                 # before a sticky tier steps back down
    delta_cap: int = 128         # delta-buffer capacity floor on first
                                 # insert (grows by doubling; DESIGN §11)
    delta_occupancy: float = 0.5  # (buffered + tombstoned) / live
                                  # fraction above which the executor
                                  # schedules a deferred re-fit
    # -- streaming serve scheduler knobs (serve/scheduler.py, §12) ----
    serve_max_batch: int = 256   # micro-batch coalescing cap (per-spec
                                 # caps from BENCH_quick.json wide-batch
                                 # columns clamp below this)
    serve_coalesce_us: int = 200  # straggler wait once a partial batch
                                  # exists (worker mode only; the
                                  # manual test mode never waits)
    serve_queue_depth: int = 4096  # backpressure bound: submit() blocks
                                   # while the queue is this deep
    serve_idle_maintain: bool = True  # run maintain() when the queue
                                      # drains (never between requests)


def exec_key(backend: str, base: Tuple, tag: str = "x",
             variant: Optional[Tuple] = None,
             qshard: bool = False, epoch: int = 0) -> Tuple:
    """Canonical executable-cache key (DESIGN.md §10/§11 layout).

    ``(backend, qshard, base, tag, variant, epoch)``:

      backend   Backend.name — compiled programs are never shared across
                kernel backends;
      qshard    True for the query-axis-sharded wrapping of the same
                program (different in/out shardings -> different
                executable);
      base      the spec's sticky/cache base tuple (``sticky_key()`` for
                adaptive ops, a literal kind tuple otherwise);
      tag       program flavor within the base: "x" exact/simple,
                "w" strict windowed tier, "fused" zero-sync steady tier,
                "u" update (insert/delete) executable;
      variant   the (cap, cand) tier for "w"/"fused" programs — the slot
                the executor's eviction policy sweeps — or the
                epoch-invariant data shapes (batch size, capacity) for
                "u" programs, so update executables cache like queries;
      epoch     the index's SHAPE epoch (not the mutation epoch): bumps
                only when a compiled-shape-relevant static changes
                (delta capacity, n_pad, knot width, probe). Executables
                stay cached across ordinary updates; `_evict_stale`
                sweeps superseded shape epochs.
    """
    return (str(backend), bool(qshard), tuple(base), str(tag), variant,
            int(epoch))


class QuerySpec:
    """Base class for declarative query descriptions.

    Subclasses are frozen dataclasses; equality and hashing follow the
    canonicalized fields, so a spec is safe to use as a cache key.
    """

    kind: str = "?"
    n_args: int = 0              # number of positional data arguments

    def plan_key(self) -> Tuple:
        """Canonical identity of this spec's compiled-program family."""
        return (self.kind,)

    def sticky_key(self) -> Tuple:
        """Identity of the shared adaptive (cap, cand) state."""
        return (self.kind,)


def _as_int(v, name: str, *, optional: bool = False,
            positive: bool = True) -> Optional[int]:
    if v is None:
        if optional:
            return None
        raise TypeError(f"{name} is required")
    v = int(v)                  # canonicalize np.int64 / bool / etc.
    if positive and v <= 0:
        raise ValueError(f"{name} must be positive, got {v}")
    return v


def _as_choice(v, name: str, choices: Tuple[str, ...]) -> str:
    v = str(v)
    if v not in choices:
        raise ValueError(f"{name} must be one of {choices}, got {v!r}")
    return v


@dataclasses.dataclass(frozen=True)
class PointQuery(QuerySpec):
    """Exact membership test. args: (qx (Q,), qy (Q,)) -> found (Q,) bool."""
    kind = "point"
    n_args = 2


@dataclasses.dataclass(frozen=True)
class RangeCount(QuerySpec):
    """Exact in-rect counts. args: (rects (Q, 4)) -> counts (Q,) int32."""
    kind = "range_count"
    n_args = 1


@dataclasses.dataclass(frozen=True)
class RangeQuery(QuerySpec):
    """Materializing windowed range query.

    args: (rects (Q, 4)) -> (counts (Q,), vids (Q, W) padded -1, ok (Q,)).
    ``cap`` optionally overrides the executor's initial per-partition
    window; the adaptive state is still shared across all RangeQuery
    instances (sticky_key "range").
    """
    kind = "range"
    n_args = 1
    cap: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "cap",
                           _as_int(self.cap, "cap", optional=True))

    def plan_key(self):
        return (self.kind, self.cap)


@dataclasses.dataclass(frozen=True)
class CircleQuery(QuerySpec):
    """Circle query via MBR window + distance refine (paper Remark 2).

    args: (cx (Q,), cy (Q,), r (Q,)).
    materialize=False -> counts (Q,) int32
    materialize=True  -> (counts (Q,), vids (Q, W) padded -1, ok (Q,))
    """
    kind = "circle"
    n_args = 3
    materialize: bool = False

    def __post_init__(self):
        object.__setattr__(self, "materialize", bool(self.materialize))

    def plan_key(self):
        return (self.kind, self.materialize)

    def sticky_key(self):
        # materializing and counting variants gather different window
        # widths — separate adaptive state
        return (self.kind, self.materialize)


@dataclasses.dataclass(frozen=True)
class Knn(QuerySpec):
    """Exact k nearest neighbours. args: (qx (Q,), qy (Q,)) ->
    (d2 (Q, k), vid (Q, k))."""
    kind = "knn"
    n_args = 2
    k: int = 10
    mode: str = "pruned"

    def __post_init__(self):
        object.__setattr__(self, "k", _as_int(self.k, "k"))
        object.__setattr__(self, "mode",
                           _as_choice(self.mode, "mode",
                                      ("pruned", "exact")))

    def plan_key(self):
        return (self.kind, self.k, self.mode)

    def sticky_key(self):
        return (self.kind, self.k)


@dataclasses.dataclass(frozen=True)
class SpatialJoin(QuerySpec):
    """Polygon-contains-points broadcast join counts.

    args: (polys (PG, E, 2), n_edges (PG,)) -> counts (PG,) int32.
    """
    kind = "join"
    n_args = 2
    mode: str = "windowed"

    def __post_init__(self):
        object.__setattr__(self, "mode",
                           _as_choice(self.mode, "mode",
                                      ("windowed", "full")))

    def plan_key(self):
        return (self.kind, self.mode)


# ---------------------------------------------------------------------------
# update specs: mutations through the same executor (DESIGN.md §11)
# ---------------------------------------------------------------------------

class UpdateSpec(QuerySpec):
    """Base class for declarative index mutations.

    Like queries, an UpdateSpec carries no data: batches are passed to
    ``Executor.run(spec, *args)`` and the jitted mutation kernels cache
    in the same executable cache, keyed by their epoch-invariant shapes
    (batch size, delta capacity) — repeated same-sized update batches
    dispatch with zero recompiles.
    """


@dataclasses.dataclass(frozen=True)
class InsertBatch(UpdateSpec):
    """Batched insert. args: (xs (B,), ys (B,)) -> assigned vids (B,).

    Points are appended to their target partition's delta buffer; the
    spline is NOT re-fit (that is deferred to ``Refit`` / the
    executor's occupancy-triggered ``maintain()`` compaction).
    """
    kind = "insert"
    n_args = 2


@dataclasses.dataclass(frozen=True)
class DeleteBatch(UpdateSpec):
    """Batched delete by coordinate. args: (xs (B,), ys (B,)) ->
    removed count (int). Removes EVERY live copy of each (x, y)."""
    kind = "delete"
    n_args = 2


@dataclasses.dataclass(frozen=True)
class Refit(UpdateSpec):
    """Compaction + per-partition spline re-fit of every dirty
    partition (buffered inserts or tombstones). args: () -> the list of
    partition ids re-fit. Targeted re-fit: ``Executor.refit(touched)``.
    """
    kind = "refit"
    n_args = 0


ALL_SPEC_TYPES = (PointQuery, RangeCount, RangeQuery, CircleQuery, Knn,
                  SpatialJoin)
ALL_UPDATE_TYPES = (InsertBatch, DeleteBatch, Refit)
