"""Unified adaptive query executor (DESIGN.md §9).

ONE place owns what the six SpatialEngine methods used to hand-roll
separately:

  (a) compilation — jit + shard_map wrapping of the local SPMD programs
      (core/local_ops.py), with an executable cache that EVICTS a
      spec's superseded cap-variants (keeps the sticky tier + the
      initial-config tier) so escalation cannot leak compiled programs
      in long-running serving;
  (b) the adaptive-cap policy — sticky last-successful (cap, cand) per
      ``spec.sticky_key()``, geometric escalation schedule, and an
      exactness-preserving final fallback;
  (c) dispatch — ``run(spec, *args)`` / ``run_batch([...])`` so mixed
      workloads enter through one door.

Two execution modes for adaptive specs:

  strict=True   the backward-compatible facade mode: host-checked
                escalation loop, identical control flow (and bitwise
                results) to the pre-plan engine. One host sync per
                attempt.
  strict=False  the serving mode: once a sticky (cap, cand) exists the
                compiled program FUSES the windowed attempt with a
                lax.cond exact fallback, so a steady-state ``run`` with
                a sticky hit performs ZERO host-side bool(jnp.all(...))
                syncs while counts stay exact. The ``ok`` flags of
                materializing specs still report window completeness.

Every QUERY-path host synchronization goes through ``_all_ok`` and is
counted in ``host_syncs`` — asserted by the dispatch-count test.
Mutations (InsertBatch/DeleteBatch/Refit, DESIGN.md §11) are
host-driven like ``build_index`` and block deliberately; they never
ride the zero-sync steady path.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import keys as K
from repro.core import mutate as M
from repro.core import queries as Q
from repro.core.backends import resolve_backend
from repro.core.build import LearnedSpatialIndex
from repro.core.plan import (CircleQuery, DeleteBatch, EngineConfig,
                             InsertBatch, Knn, PointQuery, QuerySpec,
                             RangeCount, RangeQuery, Refit, SpatialJoin,
                             exec_key)
from repro.core import local_ops as L
from repro.core.local_ops import _axes


def shard_map_fn():
    """Resolve shard_map across jax versions (jax.shard_map is new)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm


def _shard_map_wrap(fn, mesh, in_specs, out_specs):
    """shard_map with the replication-check kwarg spelling per version."""
    sm = shard_map_fn()
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return sm(fn, check_vma=False, **kw)
    except (TypeError, AttributeError):  # older jax spelling
        return sm(fn, check_rep=False, **kw)


@dataclasses.dataclass
class _AdaptiveOp:
    """Descriptor binding one query family to the shared policy loop."""
    base: Tuple                       # sticky/cache key
    initial: Tuple[int, int]          # starting (cap, cand)
    window: Callable                  # (cap, cand) -> local program
    get_ok: Callable                  # raw result -> ok array
    finalize: Callable                # raw result -> public result
    escalate: Callable                # (cap, cand) -> (cap, cand)
    maxed: Callable                   # (cap, cand) -> bool
    sticky_on_maxed: bool             # seed semantics differ per op
    fallback: Optional[Callable]      # (pargs, raw) -> exact result
    fused: Optional[Callable]         # (cap, cand) -> fused local program
    post: Callable = lambda r: r      # fused/public result adapter
    demote: Optional[Callable] = None  # (cap, cand) -> lower tier


class Executor:
    """Compiles and runs QuerySpecs against one LearnedSpatialIndex.

    mesh=None -> single-device; otherwise partitions are sharded over
    ``part_axis``. With ``query_axis`` set, batches of at least
    ``EngineConfig.query_shard_threshold`` queries additionally shard
    over that mesh axis (query args padded/unpadded host-side; each
    query-row subgroup runs the partition collectives independently).
    Local programs pull their lookup/scan stages from the kernel
    backend selected by ``EngineConfig.backend`` (core/backends.py:
    XLA reference or the Pallas TPU kernels).
    """

    def __init__(self, index: LearnedSpatialIndex,
                 mesh: Optional[Mesh] = None, part_axis: str = "data",
                 query_axis: Optional[str] = None,
                 config: Optional[EngineConfig] = None):
        self.mesh = mesh
        self.part_axis = part_axis
        self.query_axis = query_axis
        # None sentinel, not a default EngineConfig() in the signature:
        # a signature default is evaluated ONCE at import and then
        # shared by every caller
        self.cfg = config if config is not None else EngineConfig()
        self.backend = resolve_backend(self.cfg.backend)
        if query_axis is not None:
            if mesh is None:
                raise ValueError("query_axis requires a mesh")
            bad = set(_axes(query_axis)) & set(_axes(part_axis))
            if bad:
                raise ValueError(
                    f"query_axis overlaps part_axis: {sorted(bad)}")
        if mesh is not None:
            shards = int(np.prod([mesh.shape[a] for a in _axes(part_axis)]))
            index = L.pad_partitions(index, shards * self.cfg.part_chunk)
        else:
            index = L.pad_partitions(index, self.cfg.part_chunk)
        self.index = index
        self.parts = L.part_arrays(index)
        self.bounds = index.part_bounds          # (P, 4) replicated
        self.spec = index.key_spec
        b = index.key_spec.bounds
        self.area = max((b[2] - b[0]) * (b[3] - b[1]), 1e-30)
        self._recount()
        self._psharding = None
        if mesh is not None:
            self._psharding = NamedSharding(mesh, P(_axes(part_axis)))
            self.parts = jax.device_put(self.parts, self._psharding)
            self.bounds = jax.device_put(
                self.bounds, NamedSharding(mesh, P()))
        # -- mutable-index state (DESIGN.md §11) -------------------------
        nxt = int(jnp.max(index.vid))
        if index.delta_vid is not None and index.delta_cap:
            nxt = max(nxt, int(jnp.max(index.delta_vid)))
        self.next_vid = nxt + 1
        self._refit_pending = set()  # partition ids awaiting compaction
        self.updates = 0      # applied insert/delete batches
        self.refits = 0       # refit_partitions invocations
        self._cache = {}      # exec_key -> compiled callable
        self._sticky = {}     # sticky_key -> last-successful (cap, cand)
        self._initial = {}    # sticky_key -> initial-config (cap, cand)
        self._pending = {}    # sticky_key -> (tier, ok device array)
        self._escalators = {}  # sticky_key -> the op's escalate rule
        self._demoters = {}   # sticky_key -> the op's demote rule
        self._ok_streak = {}  # sticky_key -> consecutive clean checks
        self._demoted_from = {}   # sticky_key -> tier last demoted FROM
        self._demote_backoff = {}  # sticky_key -> streak multiplier
        self.host_syncs = 0   # counted bool(jnp.all(...)) blocking reads
        self.dispatches = 0   # compiled-program launches
        # serializes run/maintain/refit so the serve scheduler's worker
        # thread and direct session.submit callers can share one
        # executor (executable cache, sticky state, index swap) safely;
        # reentrant because run(Refit) and maintain() call refit()
        self._lock = threading.RLock()

    # -- compilation + executable cache ----------------------------------

    def _key(self, base, tag="x", variant=None, qshard=False):
        """Canonical cache key (plan.exec_key): backend + qshard +
        shape-epoch aware (compiled programs bake the index's static
        shapes; superseded shape epochs are swept by _evict_stale)."""
        return exec_key(self.backend.name, base, tag, variant,
                        qshard=qshard, epoch=self.index.shape_epoch)

    def _query_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a]
                            for a in _axes(self.query_axis)]))

    def _use_qshard(self, qlen: int) -> bool:
        """Shard this batch over the query mesh axis? (DESIGN.md §10)"""
        return (self.mesh is not None and self.query_axis is not None
                and qlen >= self.cfg.query_shard_threshold)

    def _pad_queries(self, fn):
        """Pad query args to a query-axis multiple; unpad all outputs.

        Pads by repeating row 0 — a real, resolvable query — so padding
        can never trip the adaptive ok flags that fused programs stash
        for maintain(). Every program output leaf carries the query
        batch as its leading axis, so unpadding is one tree_map.
        """
        qsize = self._query_shards()

        def wrapped(parts, bounds, *q):
            qlen = q[0].shape[0]
            pad = (-qlen) % qsize
            if pad:
                q = tuple(jnp.concatenate(
                    [a, jnp.repeat(a[:1], pad, axis=0)], axis=0)
                    for a in q)
            out = fn(parts, bounds, *q)
            if pad:
                out = jax.tree_util.tree_map(lambda a: a[:qlen], out)
            return out

        return wrapped

    def _compile(self, key, make_fn, qshard: bool = False):
        """jit (and shard_map when meshed) a local program, cached.

        qshard=True compiles the query-axis-sharded wrapping: query
        args shard over ``query_axis`` (partitions still shard over
        ``part_axis``; collectives inside the program stay scoped to the
        part axes, so each query-row subgroup reduces independently) and
        outputs come back query-sharded. The host-side pad/unpad rides
        on the compiled callable.
        """
        if key in self._cache:
            return self._cache[key]
        fn = make_fn()
        if self.mesh is None:
            out = jax.jit(partial(fn, axis=None))
        else:
            paxes = _axes(self.part_axis)
            if qshard:
                qaxes = _axes(self.query_axis)
                in_specs = ((P(paxes), P()) +
                            (P(qaxes),) * fn.n_query_args)
                out_specs = P(qaxes)
            else:
                in_specs = (P(paxes),) + (P(),) * (fn.n_query_args + 1)
                out_specs = P()
            wrapped = _shard_map_wrap(partial(fn, axis=paxes), self.mesh,
                                      in_specs, out_specs)
            out = jax.jit(wrapped)
            if qshard:
                out = self._pad_queries(out)
        self._cache[key] = out
        return out

    def _call(self, fn, *args):
        self.dispatches += 1
        return fn(self.parts, self.bounds, *args)

    def _all_ok(self, ok) -> bool:
        """The ONLY host-blocking read on the QUERY path (counted)."""
        self.host_syncs += 1
        return bool(jnp.all(ok))

    def _set_sticky(self, base, variant):
        old = self._sticky.get(base)
        self._sticky[base] = variant
        if old != variant:
            # a new tier starts its demotion clock from zero — clean
            # checks at the PREVIOUS tier must not count toward
            # demote_after at this one
            self._ok_streak[base] = 0
            self._evict(base)

    def _evict(self, base):
        """Drop superseded cap-variants: keep sticky + initial tier.

        Escalated ``(cap, cand)`` executables for smaller caps are dead
        weight once a larger sticky tier is established — without this,
        long-running serving leaks one compiled program per escalation
        step (the seed engine's ``_jits`` bug). Sweeps both the plain
        and query-sharded wrappings (plan.exec_key layout).
        """
        keep = {self._sticky.get(base), self._initial.get(base)}
        for key in list(self._cache):
            if (key[2] == tuple(base) and key[3] in ("w", "fused") and
                    key[4] not in keep):
                del self._cache[key]

    def _evict_stale(self):
        """Drop executables whose index shape epoch is superseded.

        Cap-variant eviction (_evict) only sweeps one plan key; without
        this sweep a long-lived serve session leaks every compiled
        program across updates that change a static shape (delta
        capacity growth, n_pad/knot widening, probe refresh).
        """
        cur = self.index.shape_epoch
        for key in list(self._cache):
            if key[5] != cur:
                del self._cache[key]

    def cache_variants(self, base) -> list:
        """Cached (tag, (cap, cand)) window variants for one sticky key."""
        return sorted((k[3], k[4]) for k in self._cache
                      if k[2] == tuple(base) and k[3] in ("w", "fused"))

    def cache_keys(self) -> list:
        """All executable-cache keys (plan.exec_key layout) — used by
        tests/tools to assert backend and query-shard compilation."""
        return list(self._cache)

    def stats(self) -> dict:
        return {"host_syncs": self.host_syncs,
                "dispatches": self.dispatches,
                "cache_size": len(self._cache),
                "backend": self.backend.name,
                "qshard_executables": sum(1 for k in self._cache if k[1]),
                "sticky": dict(self._sticky),
                "epoch": self.index.epoch,
                "shape_epoch": self.index.shape_epoch,
                "updates": self.updates,
                "refits": self.refits,
                "pending_refit": sorted(self._refit_pending)}

    @property
    def epoch(self) -> int:
        """Mutation epoch of the resident index — the read-your-writes
        barrier token the serve scheduler stamps on request tickets
        (a read dispatched after a write sees an epoch >= the write's).
        """
        return self.index.epoch

    def maintenance_due(self) -> bool:
        """Deferred maintain() work waiting? (stashed ok flags from
        zero-sync runs, or occupancy-scheduled compactions) — the serve
        scheduler polls this at queue-idle time so maintenance never
        rides the hot path."""
        return bool(self._pending) or bool(self._refit_pending)

    # -- mutable-index state management (DESIGN.md §11) ------------------

    def _recount(self):
        """Refresh the live-point total + density (kNN r0 seeding)."""
        idx = self.index
        n = int(jnp.sum(idx.count))
        if idx.dead is not None:
            n -= int(jnp.sum(idx.dead))
        if idx.delta_vid is not None and idx.delta_cap:
            n += int(jnp.sum((idx.delta_vid >= 0).astype(jnp.int32)))
        self.n_total = n
        self.density = max(n / self.area, 1e-30)

    def _install_index(self, new_index, leaves=None):
        """Swap in a mutated index: refresh the (possibly sharded) parts
        leaves and evict executables compiled against superseded static
        shapes. ``leaves`` limits the refresh to the planes a mutation
        actually touched (inserts never re-place the sorted data plane).
        """
        shape_changed = new_index.shape_epoch != self.index.shape_epoch
        self.index = new_index
        names = L.part_leaf_names(new_index)
        if (shape_changed or leaves is None
                or names != set(self.parts)):
            leaves = names
        upd = L.part_arrays(new_index, leaves=leaves)
        if self.mesh is not None:
            upd = {k: jax.device_put(v, self._psharding)
                   for k, v in upd.items()}
        parts = dict(self.parts)
        parts.update(upd)
        self.parts = {k: parts[k] for k in names}
        self.bounds = new_index.part_bounds    # (P, 4): cheap, always
        if self.mesh is not None:
            self.bounds = jax.device_put(
                self.bounds, NamedSharding(self.mesh, P()))
        if shape_changed:
            self._evict_stale()
        self._recount()

    def _update_fn(self, kind: str, b: int, fn):
        """Update executables cache like queries: one jitted instance
        per (batch size, delta capacity) variant, so `_evict_stale`
        sweeping a superseded shape epoch actually frees its compiled
        programs (the mutate kernels are exported unjitted)."""
        key = self._key((kind,), "u", (b, self.index.delta_cap))
        if key not in self._cache:
            self._cache[key] = jax.jit(fn)
        self.dispatches += 1
        return self._cache[key]

    def _note_occupancy(self, touched):
        """Schedule deferred compaction+re-fit for partitions whose
        delta occupancy crossed the threshold (executed by maintain(),
        off the hot path — exactly like tier demotion)."""
        occ = M.delta_occupancy(self.index)
        for p in np.asarray(touched).tolist():
            if occ[p] > self.cfg.delta_occupancy:
                self._refit_pending.add(int(p))

    def _run_insert(self, args):
        """InsertBatch: append to the target partitions' delta buffers.
        Returns the assigned vids (B,). Host-driven like build_index —
        the capacity check is a blocking read, off the query hot path.
        """
        xs = jnp.asarray(args[0], jnp.float32)
        ys = jnp.asarray(args[1], jnp.float32)
        b = int(xs.shape[0])
        if b == 0:
            return np.zeros((0,), np.int32)
        idx = self.index
        if idx.delta_count is None:      # hand-built index: add aux state
            idx = M.with_delta_capacity(idx, 0, floor=0)
            self._install_index(idx)
        pid = M.assign_insert(idx, xs, ys)
        # out-of-domain inserts land in the overflow grid; widen its box
        # so the global filter (rect/circle/kNN/join candidate
        # selection) can SEE them — otherwise only the point probe,
        # which targets overflow unconditionally, would find them.
        # (Keys still clip to key_spec.bounds; the coordinate refine is
        # exact on the stored f32 coords, so counts stay right.)
        ob = np.asarray(idx.part_bounds[idx.overflow])
        nb = [min(ob[0], float(xs.min())), min(ob[1], float(ys.min())),
              max(ob[2], float(xs.max())), max(ob[3], float(ys.max()))]
        if nb != ob.tolist():
            idx = dataclasses.replace(
                idx, part_bounds=idx.part_bounds.at[idx.overflow].set(
                    jnp.asarray(nb, jnp.float32)))
            self._install_index(idx, leaves=())
        need = np.asarray(idx.delta_count) + np.bincount(
            np.asarray(pid), minlength=idx.num_partitions)
        if int(need.max()) > idx.delta_cap:
            idx = M.with_delta_capacity(idx, int(need.max()),
                                        floor=self.cfg.delta_cap)
            self._install_index(idx)     # shape change: evict + refresh
        key = K.make_keys(xs, ys, self.spec)
        vids = jnp.arange(self.next_vid, self.next_vid + b,
                          dtype=jnp.int32)
        fn = self._update_fn("insert", b, M.scatter_inserts)
        dk, dx, dy, dv, dc = fn(idx.delta_key, idx.delta_x, idx.delta_y,
                                idx.delta_vid, idx.delta_count, pid,
                                key, xs, ys, vids)
        idx = dataclasses.replace(
            idx, delta_key=dk, delta_x=dx, delta_y=dy, delta_vid=dv,
            delta_count=dc, epoch=idx.epoch + 1)
        self.next_vid += b
        self.updates += 1
        self._install_index(idx, leaves=("dx", "dy", "dvid", "dcount"))
        self._note_occupancy(np.unique(np.asarray(pid)))
        return np.arange(self.next_vid - b, self.next_vid, dtype=np.int32)

    def _run_delete(self, args):
        """DeleteBatch: tombstone every live copy of each (x, y) in its
        candidate partitions (main plane + delta). Returns the removed
        count."""
        xs = jnp.asarray(args[0], jnp.float32)
        ys = jnp.asarray(args[1], jnp.float32)
        b = int(xs.shape[0])
        if b == 0:
            return 0
        idx = self.index
        if idx.delta_count is None:      # hand-built index: add aux state
            idx = M.with_delta_capacity(idx, 0, floor=0)
            self._install_index(idx)
        pid1 = M.assign_insert(idx, xs, ys)
        pid2 = jnp.full_like(pid1, idx.overflow)
        fn = self._update_fn("delete", b, M.apply_deletes)
        nx, ny, nv, dx, dy, dv, dead2, removed = fn(
            idx.x, idx.y, idx.vid, idx.count, idx.delta_x, idx.delta_y,
            idx.delta_vid, idx.delta_count, idx.dead, xs, ys, pid1, pid2)
        idx = dataclasses.replace(
            idx, x=nx, y=ny, vid=nv, delta_x=dx, delta_y=dy,
            delta_vid=dv, dead=dead2, epoch=idx.epoch + 1)
        self.updates += 1
        leaves = ("x", "y", "vid")
        if idx.delta_cap:
            leaves = leaves + ("dx", "dy", "dvid")
        self._install_index(idx, leaves=leaves)
        self._note_occupancy(np.unique(np.append(np.asarray(pid1),
                                                 idx.overflow)))
        return int(removed)

    def refit(self, touched=None):
        """Compaction + per-partition spline re-fit (mutate.refit_
        partitions): merge delta buffers, drop tombstones, re-fit ONLY
        the given partitions (default: every dirty one). Returns the
        list of partition ids re-fit. Thread-safe."""
        with self._lock:
            return self._refit_locked(touched)

    def _refit_locked(self, touched=None):
        idx = self.index
        if idx.delta_count is None:
            return []
        if touched is None:
            touched = M.dirty_partitions(idx)
        touched = np.unique(np.asarray(touched, np.int32))
        if touched.size == 0:
            return []
        new = M.refit_partitions(idx, touched)
        self.refits += 1
        self._refit_pending.difference_update(int(t) for t in touched)
        self._install_index(new)         # data plane moved: full refresh
        # shed a burst-grown delta buffer once fully compacted (the 2x
        # floor hysteresis rate-limits grow/shrink compile ping-pong)
        idx2 = self.index
        if (idx2.delta_cap > 2 * max(self.cfg.delta_cap, 1)
                and M.dirty_partitions(idx2).size == 0):
            self._install_index(
                M.shrink_delta_capacity(idx2, self.cfg.delta_cap))
        return [int(t) for t in touched]

    def maintain(self) -> dict:
        """Deferred re-tuning: host-check the stashed ok flags of recent
        zero-sync runs; escalate sticky tiers that overflowed and DEMOTE
        tiers that have been clean for ``EngineConfig.demote_after``
        consecutive checks (the online re-tune loop in both directions —
        a hard burst no longer pins a spec at its peak tier forever).

        Call OFF the serving hot path (between batches, on a timer).
        Counts stay exact either way — overflowed fused runs already
        fell back on device — but escalating restores complete
        materialization windows and stops paying the fallback cost
        every request, while demoting sheds the peak tier's window cost
        once traffic gets easier. A demotion that immediately bounces
        back (the next overflow escalates to the tier it left) DOUBLES
        that base's required clean streak (exponential backoff), so
        steady-state serving rate-limits ping-pong compiles without
        ever disabling downward re-tuning for good. Returns
        {sticky_key: new (cap, cand)} for the tiers that moved.
        Thread-safe (the serve scheduler runs this at queue-idle time).
        """
        with self._lock:
            return self._maintain_locked()

    def _maintain_locked(self) -> dict:
        moved = {}
        for base, (tier, ok) in list(self._pending.items()):
            del self._pending[base]
            if self._sticky.get(base) != tier:
                continue   # stale: sticky already moved since the stash
            if self._all_ok(ok):
                streak = self._ok_streak.get(base, 0) + 1
                self._ok_streak[base] = streak
                # the demoted tier survived a clean check: it was a real
                # demotion, not a bounce — forget the provenance so a
                # LATER escalation through this tier is not billed as
                # ping-pong
                self._demoted_from.pop(base, None)
                demote = self._demoters.get(base)
                need = (self.cfg.demote_after *
                        self._demote_backoff.get(base, 1))
                if demote is None or streak < need:
                    continue
                new = demote(*tier)
                if new != tier:
                    self._demoted_from[base] = tier
                    self._set_sticky(base, new)
                    moved[base] = new
                continue
            self._ok_streak[base] = 0
            new = self._escalators[base](*tier)
            if new != tier:
                if self._demoted_from.pop(base, None) == new:
                    # immediate bounce: back off, don't veto forever
                    self._demote_backoff[base] = \
                        self._demote_backoff.get(base, 1) * 2
                self._set_sticky(base, new)
                moved[base] = new
        # deferred compaction + re-fit, scheduled by updates whose delta
        # occupancy crossed the threshold — executed here, off the hot
        # path, exactly like tier re-tuning (DESIGN.md §11)
        if self._refit_pending:
            done = self.refit(sorted(self._refit_pending))
            if done:
                moved["refit"] = done
        return moved

    # -- public entry points ---------------------------------------------

    def run(self, spec: QuerySpec, *args, strict: bool = False):
        """Execute one QuerySpec. See class docstring for ``strict``.

        Thread-safe: the executor lock serializes dispatch (executable
        cache, sticky state, index swap) so the serve scheduler's
        worker and direct callers can share one executor."""
        if not isinstance(spec, QuerySpec):
            raise TypeError(f"expected a QuerySpec, got {spec!r}")
        if len(args) != spec.n_args:
            raise TypeError(f"{type(spec).__name__} takes {spec.n_args} "
                            f"data arguments, got {len(args)}")
        with self._lock:
            if isinstance(spec, InsertBatch):
                return self._run_insert(args)
            if isinstance(spec, DeleteBatch):
                return self._run_delete(args)
            if isinstance(spec, Refit):
                return self.refit()
            if isinstance(spec, PointQuery):
                return self._run_point(args)
            if isinstance(spec, RangeCount):
                return self._run_range_count(args)
            if isinstance(spec, RangeQuery):
                return self._run_range(spec, args, strict)
            if isinstance(spec, CircleQuery):
                return self._run_circle(spec, args, strict)
            if isinstance(spec, Knn):
                return self._run_knn(spec, args, strict)
            if isinstance(spec, SpatialJoin):
                return self._run_join(spec, args, strict)
        raise TypeError(f"unknown QuerySpec: {spec!r}")

    def run_batch(self, requests, strict: bool = False) -> list:
        """Execute a mixed workload: iterable of (spec, *args) tuples.

        Returns results in request order. Steady-state batches (every
        spec sticky-hit) dispatch with zero host syncs.
        """
        return [self.run(req[0], *req[1:], strict=strict)
                for req in requests]

    # -- shared adaptive policy ------------------------------------------

    def _adaptive(self, op: _AdaptiveOp, pargs, strict: bool,
                  start: Optional[Tuple[int, int]] = None):
        """Sticky + geometric escalation + exact fallback — ONCE.

        Replaces the divergent copies the seed engine kept in
        range_query / knn / join_count. ``start`` marks a one-off
        user-tier override: it never UPDATES the shared sticky state,
        so a single cheap capped query cannot downgrade the serving
        tier (and evict its compiled fused executable).
        """
        self._initial.setdefault(op.base, op.initial)
        self._escalators[op.base] = op.escalate
        self._demoters[op.base] = op.demote
        sticky = self._sticky.get(op.base)
        qs = self._use_qshard(pargs[0].shape[0])
        if (sticky is not None and not strict and op.fused is not None
                and start is None):
            # steady state: fused windowed+fallback program, no host
            # sync; the ok flags are stashed (not read) so maintain()
            # can re-tune the sticky tier off the hot path
            fn = self._compile(self._key(op.base, "fused", sticky,
                                         qshard=qs),
                               lambda: op.fused(*sticky), qshard=qs)
            out, ok = self._call(fn, *pargs)
            self._pending[op.base] = (sticky, ok)
            return op.post(out)
        cap, cand = start or sticky or op.initial
        while True:
            fn = self._compile(self._key(op.base, "w", (cap, cand),
                                         qshard=qs),
                               lambda: op.window(cap, cand), qshard=qs)
            res = self._call(fn, *pargs)
            hit = self._all_ok(op.get_ok(res))
            maxed = op.maxed(cap, cand)
            if hit or (maxed and op.sticky_on_maxed):
                if start is None:
                    self._set_sticky(op.base, (cap, cand))
                return op.finalize(res)
            if maxed:
                break
            cap, cand = op.escalate(cap, cand)
        return op.fallback(pargs, res)

    def _maxed_both(self, cap, cand):
        return (cap >= self.index.n_pad and
                cand >= self.index.num_partitions)

    def _escalate_both(self, cap, cand):
        return (min(cap * 4, self.index.n_pad),
                min(cand * 2, self.index.num_partitions))

    def _ladder_demote(self, initial, escalate):
        """Demote to the PREDECESSOR on the op's actual escalation
        ladder (initial, escalate(initial), ...) rather than a naive
        cap//4 inverse — when escalation clamped at n_pad /
        num_partitions the arithmetic inverse lands on off-ladder tiers
        that were never compiled, and demotion would churn fresh
        executables instead of reusing warm ones."""
        def demote(cap, cand):
            prev = cur = initial
            for _ in range(64):          # ladders are O(log) long
                if cur == (cap, cand):
                    return prev
                nxt = escalate(*cur)
                if nxt == cur:
                    break                # maxed without finding it
                prev, cur = cur, nxt
            return (cap, cand)           # off-ladder: stay put
        return demote

    # -- per-kind preparation + drivers ----------------------------------

    def _qkeys(self, qx, qy):
        return K.keys_to_f32(K.make_keys(qx, qy, self.spec))

    def _rect_keys(self, rects):
        klo, khi = K.rect_key_range(rects, self.spec)
        return K.keys_to_f32(klo), K.keys_to_f32(khi)

    def _run_point(self, args):
        qx = jnp.asarray(args[0], jnp.float32)
        qy = jnp.asarray(args[1], jnp.float32)
        qk = self._qkeys(qx, qy)
        qs = self._use_qshard(qx.shape[0])
        fn = self._compile(self._key(("point",), qshard=qs),
                           lambda: L._PointLocal(self.index, self.cfg,
                                                 self.backend),
                           qshard=qs)
        return self._call(fn, qx, qy, qk) > 0

    def _run_range_count(self, args):
        rects = jnp.asarray(args[0], jnp.float32)
        klo, khi = self._rect_keys(rects)
        qs = self._use_qshard(rects.shape[0])
        fn = self._compile(self._key(("range_count",), qshard=qs),
                           lambda: L._RangeCountLocal(self.index,
                                                      self.cfg,
                                                      self.backend),
                           qshard=qs)
        return self._call(fn, rects, klo, khi)

    def _op_range(self, base):
        idx, cfg, bk = self.index, self.cfg, self.backend

        def fused(cap, cand):
            # counts stay exact via the on-device full-refine fallback;
            # ok still flags per-query materialization completeness
            return L._CondFusedLocal(
                idx, cfg, bk,
                primary=L._RangeWindowLocal(idx, cfg, bk, cap, cand),
                fallback=L._RangeCountLocal(idx, cfg, bk),
                fb_args=(0, 1, 2),
                get_ok=lambda pri: pri[2],
                merge_ok=lambda pri: pri,
                merge_fb=lambda pri, fb: (fb, pri[1], pri[2]))

        return _AdaptiveOp(
            base=base, initial=(cfg.range_cap, cfg.range_cand),
            window=lambda cap, cand: L._RangeWindowLocal(idx, cfg, bk,
                                                         cap, cand),
            get_ok=lambda res: res[2], finalize=lambda res: res,
            escalate=self._escalate_both, maxed=self._maxed_both,
            sticky_on_maxed=True, fallback=None, fused=fused,
            demote=self._ladder_demote((cfg.range_cap, cfg.range_cand),
                                       self._escalate_both))

    def _run_range(self, spec: RangeQuery, args, strict):
        rects = jnp.asarray(args[0], jnp.float32)
        klo, khi = self._rect_keys(rects)
        op = self._op_range(spec.sticky_key())
        start = None
        if spec.cap is not None:
            # user cap overrides the starting tier; cand follows sticky
            _, cand0 = self._sticky.get(op.base, op.initial)
            start = (min(spec.cap, self.index.n_pad), cand0)
        return self._adaptive(op, (rects, klo, khi), strict, start=start)

    def _op_circle(self, base, materialize: bool):
        idx, cfg, bk = self.index, self.cfg, self.backend

        def window(cap, cand):
            return L._CircleWindowLocal(idx, cfg, bk, cap, cand,
                                        materialize)

        def fused(cap, cand):
            if materialize:
                return L._CondFusedLocal(
                    idx, cfg, bk, primary=window(cap, cand),
                    fallback=L._CircleCountLocal(idx, cfg, bk),
                    fb_args=(0, 1, 2, 3),
                    get_ok=lambda pri: pri[2],
                    merge_ok=lambda pri: pri,
                    merge_fb=lambda pri, fb: (fb, pri[1], pri[2]))
            return L._CondFusedLocal(
                idx, cfg, bk, primary=window(cap, cand),
                fallback=L._CircleCountLocal(idx, cfg, bk),
                fb_args=(0, 1, 2, 3),
                get_ok=lambda pri: pri[1],
                merge_ok=lambda pri: pri[0],
                merge_fb=lambda pri, fb: fb)

        def fallback(pargs, res):
            qs = self._use_qshard(pargs[0].shape[0])
            fn = self._compile(self._key(("circle_exact",), qshard=qs),
                               lambda: L._CircleCountLocal(idx, cfg, bk),
                               qshard=qs)
            cnt = self._call(fn, *pargs)
            if materialize:    # exact counts; window ids flagged by ok
                return cnt, res[1], res[2]
            return cnt

        return _AdaptiveOp(
            base=base,
            initial=(cfg.circle_cap, cfg.circle_cand), window=window,
            get_ok=lambda res: res[-1],
            finalize=(lambda res: res) if materialize
            else (lambda res: res[0]),
            escalate=self._escalate_both, maxed=self._maxed_both,
            sticky_on_maxed=False, fallback=fallback, fused=fused,
            demote=self._ladder_demote((cfg.circle_cap, cfg.circle_cand),
                                       self._escalate_both))

    def _run_circle(self, spec: CircleQuery, args, strict):
        cx = jnp.asarray(args[0], jnp.float32)
        cy = jnp.asarray(args[1], jnp.float32)
        r = jnp.asarray(args[2], jnp.float32)
        rects = jnp.stack([cx - r, cy - r, cx + r, cy + r], axis=-1)
        klo, khi = self._rect_keys(rects)
        circ = jnp.stack([cx, cy, r], axis=-1)
        op = self._op_circle(spec.sticky_key(), spec.materialize)
        return self._adaptive(op, (rects, klo, khi, circ), strict)

    def _knn_r0(self, qx, qy, k):
        # Paper Eq. (1): r = sqrt(k / (pi * d)) — refined with the LOCAL
        # density of each query's nearest partition (beyond-paper: the
        # global-density estimate needs many expansion rounds in sparse
        # regions; the per-partition counts are free in the global index)
        r0g = float(np.sqrt(max(k, 1) / (np.pi * self.density)))
        bd2 = Q.box_min_dist2(qx, qy, self.bounds)
        pid0 = jnp.argmin(bd2, axis=1)
        b0 = self.bounds[pid0]
        area0 = jnp.maximum((b0[:, 2] - b0[:, 0]) *
                            (b0[:, 3] - b0[:, 1]), 1e-30)
        d0 = jnp.maximum(self.index.count[pid0] / area0, 1e-30)
        r0 = jnp.sqrt(k / (jnp.pi * d0)).astype(jnp.float32)
        return jnp.maximum(r0, r0g)

    def _knn_exact_fn(self, k, qshard: bool = False):
        return self._compile(self._key(("knn_exact", k), qshard=qshard),
                             lambda: L._KnnExactLocal(self.index,
                                                      self.cfg,
                                                      self.backend, k),
                             qshard=qshard)

    def _op_knn(self, base, k):
        idx, cfg, bk = self.index, self.cfg, self.backend
        cand = cfg.knn_cand

        def window(cap, _cand):
            return L._KnnPrunedLocal(idx, cfg, bk, k, self.spec, cand,
                                     cap)

        def fused(cap, _cand):
            def merge_fb(pri, fb):
                okc = pri[2][:, None]
                return (jnp.where(okc, pri[0], fb[0]),
                        jnp.where(okc, pri[1], fb[1]))

            return L._CondFusedLocal(
                idx, cfg, bk, primary=window(cap, cand),
                fallback=L._KnnExactLocal(idx, cfg, bk, k),
                fb_args=(0, 1),
                get_ok=lambda pri: pri[2],
                merge_ok=lambda pri: (pri[0], pri[1]),
                merge_fb=merge_fb)

        def fallback(pargs, res):
            # final fallback for unresolved queries: exact scan
            neg, vid, ok = res
            qs = self._use_qshard(pargs[0].shape[0])
            nege, vide = self._call(self._knn_exact_fn(k, qshard=qs),
                                    *pargs[:2])
            okc = ok[:, None]
            return (jnp.where(okc, -neg, -nege),
                    jnp.where(okc, vid, vide))

        return _AdaptiveOp(
            base=base, initial=(cfg.knn_cap, cand), window=window,
            get_ok=lambda res: res[2],
            finalize=lambda res: (-res[0], res[1]),
            escalate=lambda cap, cd: (min(cap * 4, idx.n_pad), cd),
            maxed=lambda cap, cd: cap >= idx.n_pad,
            sticky_on_maxed=False, fallback=fallback, fused=fused,
            post=lambda r: (-r[0], r[1]),
            demote=lambda cap, cd: (max(cap // 4, cfg.knn_cap), cd))

    def _run_knn(self, spec: Knn, args, strict):
        qx = jnp.asarray(args[0], jnp.float32)
        qy = jnp.asarray(args[1], jnp.float32)
        if spec.mode == "exact":
            qs = self._use_qshard(qx.shape[0])
            neg, vid = self._call(self._knn_exact_fn(spec.k, qshard=qs),
                                  qx, qy)
            return -neg, vid
        r0 = self._knn_r0(qx, qy, spec.k)
        op = self._op_knn(spec.sticky_key(), spec.k)
        return self._adaptive(op, (qx, qy, r0), strict)

    def _op_join(self, base):
        idx, cfg, bk = self.index, self.cfg, self.backend

        def fused(cap, cand):
            return L._CondFusedLocal(
                idx, cfg, bk,
                primary=L._JoinLocal(idx, cfg, bk, cap, cand),
                fallback=L._JoinFullLocal(idx, cfg, bk),
                fb_args=(0, 1, 2),
                get_ok=lambda pri: pri[1],
                merge_ok=lambda pri: pri[0],
                merge_fb=lambda pri, fb: fb)

        def fallback(pargs, res):
            qs = self._use_qshard(pargs[0].shape[0])
            fn = self._compile(self._key(("join_full",), qshard=qs),
                               lambda: L._JoinFullLocal(idx, cfg, bk),
                               qshard=qs)
            return self._call(fn, *pargs)

        return _AdaptiveOp(
            base=base, initial=(cfg.join_cap, cfg.join_cand),
            window=lambda cap, cand: L._JoinLocal(idx, cfg, bk, cap,
                                                  cand),
            get_ok=lambda res: res[1], finalize=lambda res: res[0],
            escalate=self._escalate_both, maxed=self._maxed_both,
            sticky_on_maxed=False, fallback=fallback, fused=fused,
            demote=self._ladder_demote((cfg.join_cap, cfg.join_cand),
                                       self._escalate_both))

    def _run_join(self, spec: SpatialJoin, args, strict):
        polys = jnp.asarray(args[0], jnp.float32)
        n_edges = jnp.asarray(args[1], jnp.int32)
        em = L._edge_mask(polys, n_edges)
        mbrs = jnp.concatenate([
            jnp.min(jnp.where(em, polys, 3e38), axis=1),
            jnp.max(jnp.where(em, polys, -3e38), axis=1)], axis=-1)
        klo, khi = self._rect_keys(mbrs)
        mbr_k = jnp.concatenate([mbrs, klo[:, None], khi[:, None]],
                                axis=-1)
        pargs = (polys, n_edges, mbr_k)
        if spec.mode == "full":
            qs = self._use_qshard(polys.shape[0])
            fn = self._compile(self._key(("join_full",), qshard=qs),
                               lambda: L._JoinFullLocal(self.index,
                                                        self.cfg,
                                                        self.backend),
                               qshard=qs)
            return self._call(fn, *pargs)
        op = self._op_join(spec.sticky_key())
        return self._adaptive(op, pargs, strict)
