"""Distributed index build (paper §3.1 Alg. 1 + §3.2).

Pipeline (all shapes static after the host sizes them):
  1. assign: point -> grid id (vectorized first-match containment, the
     paper's per-object loop as a masked argmax; misses -> overflow id).
  2. shuffle: ONE global sort by the uint32 composite (pid << key_bits) |
     morton_key — Spark's re-partition + per-partition sort collapsed into
     a single O(N log N) radix-sortable pass.
  3. layout: scatter into dense (P, N_pad) padded rows (sentinel keys).
  4. learn: per-partition greedy spline + radix table via vmap(scan) —
     the mapPartitions step, no cross-partition communication.

Total build complexity O(N log N + N), vs STR R-tree
O(N log N + N log f * log_f N) — the paper's claimed 1.5-2x build saving.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core import radix as R
from repro.core import spline as S
from repro.core.partitioner import Partitioner

PAD_COORD = jnp.float32(3.0e38)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LearnedSpatialIndex:
    """Per-partition learned index arrays (a pytree) + static metadata.

    The state splits into immutable GEOMETRY (the sorted data plane +
    the learned model, rebuilt only by ``build_index`` /
    ``mutate.refit_partitions``) and a per-partition DELTA BUFFER
    (capacity-padded insert slots + tombstone bookkeeping) that absorbs
    batched inserts/deletes between re-fits (DESIGN.md §11):

      - deletes keep the sorted ``key`` row intact (the spline stays
        valid) and tombstone the slot by poisoning its coordinates to
        ``PAD_COORD`` and its vid to -1 — every coordinate-refine scan
        then excludes it with NO extra masking, on both kernel
        backends;
      - inserts append to the partition's delta slots; query scans
        probe the (tiny) delta buffer alongside the learned window;
      - ``mutate.refit_partitions`` merges delta + drops tombstones and
        re-fits the spline for ONLY the touched partitions.

    ``epoch`` counts applied mutations; ``shape_epoch`` bumps only when
    a compiled-shape-relevant static changes (delta capacity, n_pad,
    knot width, probe) — executables cache across epochs and are
    evicted on shape_epoch changes (executor `_evict_stale`).
    """

    # --- data plane: (P, n_pad), sorted by key within row ---
    key: jax.Array          # uint32, sentinel-padded
    x: jax.Array            # f32
    y: jax.Array            # f32
    vid: jax.Array          # int32 original point id, -1 pad
    count: jax.Array        # (P,) int32 valid points per partition
    # --- learned model: (P, m_pad) / (P, 2^b+2) ---
    knot_keys: jax.Array    # f32
    knot_pos: jax.Array     # f32
    n_knots: jax.Array      # (P,) int32
    radix_table: jax.Array  # int32
    radix_kmin: jax.Array   # (P,) f32
    radix_scale: jax.Array  # (P,) f32
    # --- global index: (P, 4) partition boxes (replicated, tiny) ---
    part_bounds: jax.Array  # f32
    # --- mutable state: delta buffer + tombstone/refit bookkeeping ---
    delta_key: Optional[jax.Array] = None    # (P, d_cap) uint32
    delta_x: Optional[jax.Array] = None      # (P, d_cap) f32
    delta_y: Optional[jax.Array] = None      # (P, d_cap) f32
    delta_vid: Optional[jax.Array] = None    # (P, d_cap) int32, -1 dead
    delta_count: Optional[jax.Array] = None  # (P,) int32 used slots
    dead: Optional[jax.Array] = None         # (P,) int32 tombstoned rows
    max_run: Optional[jax.Array] = None      # (P,) int32 longest dup run
    refit_gen: Optional[jax.Array] = None    # (P,) int32 refit counter
    # --- static (aux) ---
    eps: int = dataclasses.field(metadata=dict(static=True), default=32)
    radix_bits: int = dataclasses.field(metadata=dict(static=True), default=10)
    probe: int = dataclasses.field(metadata=dict(static=True), default=64)
    key_spec: K.KeySpec = dataclasses.field(
        metadata=dict(static=True), default_factory=K.KeySpec)
    epoch: int = dataclasses.field(metadata=dict(static=True), default=0)
    shape_epoch: int = dataclasses.field(
        metadata=dict(static=True), default=0)
    overflow_pid: int = dataclasses.field(
        metadata=dict(static=True), default=-1)

    @property
    def num_partitions(self) -> int:
        return self.key.shape[0]

    @property
    def n_pad(self) -> int:
        return self.key.shape[1]

    @property
    def delta_cap(self) -> int:
        """Static per-partition delta-slot capacity (0 = no buffer)."""
        return 0 if self.delta_key is None else self.delta_key.shape[1]

    @property
    def overflow(self) -> int:
        """Partition id of the overflow grid (paper §3.1). Indexes built
        before the mutable-state split default to the last partition —
        correct pre-padding, preserved by ``pad_partitions`` since."""
        return (self.overflow_pid if self.overflow_pid >= 0
                else self.num_partitions - 1)

    def size_bytes(self) -> dict:
        """Index-only footprint (the paper's 'lightweight' claim)."""
        model = (self.knot_keys.size + self.knot_pos.size) * 4 + \
            self.radix_table.size * 4 + self.n_knots.size * 4 + \
            (self.radix_kmin.size + self.radix_scale.size) * 4
        global_index = self.part_bounds.size * 4
        return {"local_model": int(model), "global_index": int(global_index)}


# ---------------------------------------------------------------------------
# step 1: assignment
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("chunk",))
def assign_partitions(x, y, boxes, *, chunk: int = 1 << 20):
    """First-match grid id per point; misses -> G (overflow). O(N*G)."""
    del chunk  # single fused pass; callers chunk at the host level if needed
    # (N, 1) vs (G,) broadcasting
    xl, yl, xh, yh = boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3]
    inside = ((x[:, None] >= xl) & (x[:, None] <= xh) &
              (y[:, None] >= yl) & (y[:, None] <= yh))
    hit = jnp.any(inside, axis=1)
    first = jnp.argmax(inside, axis=1).astype(jnp.int32)
    return jnp.where(hit, first, boxes.shape[0]).astype(jnp.int32)


def probe_for(eps: int, max_run: int, n_pad: int) -> int:
    """Probe-window width for exact lower bounds: a centered window of
    twice (eps + max_run) rounded up to a power of two. The greedy
    corridor's interpolation error can reach 2*eps at a restart (the
    new anchor is a data point up to eps off the fitted line); the
    power-of-two round-up headroom covers that overshoot in practice,
    and ``mutate.verify_eps`` exposes the measured error as a host
    diagnostic (tests re-check it per touched partition after every
    re-fit). Shared by build and per-partition re-fit, so a fully
    refit index sizes its window exactly like a fresh build."""
    probe = int(2 ** np.ceil(np.log2(2 * (eps + max_run) + 4)))
    return min(probe, n_pad)


# ---------------------------------------------------------------------------
# steps 2-4: shuffle + layout + learn
# ---------------------------------------------------------------------------

def build_index(x, y, partitioner: Partitioner, *,
                key_spec: K.KeySpec | None = None, eps: int = 32,
                radix_bits: int = 10, m_pad: int | None = None,
                n_pad: int | None = None, vid=None,
                delta_cap: int = 0) -> LearnedSpatialIndex:
    """Build the full distributed learned index (host entry point).

    Host-level sizing (n_pad / m_pad / probe window) is data-dependent but
    becomes STATIC in the returned index, keeping every query jit-able with
    fixed shapes.

    ``vid`` optionally overrides the per-point ids (default: position in
    the input arrays) — used to rebuild an index bitwise-equivalent to a
    mutated one (tests/test_updates.py). ``delta_cap`` pre-allocates the
    per-partition insert-slot capacity (the executor grows it on demand).
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    boxes = jnp.asarray(partitioner.partition_bounds()[:-1])  # (G, 4)
    if key_spec is None:
        key_spec = K.KeySpec(bounds=partitioner.bounds)

    pid = assign_partitions(x, y, boxes)
    key = K.make_keys(x, y, key_spec)

    p_total = partitioner.num_partitions  # G + 1 (overflow)
    kb = key_spec.key_bits
    if p_total > (1 << (32 - kb)):
        raise ValueError("too many partitions for uint32 composite key")

    composite = (pid.astype(jnp.uint32) << kb) | key
    order = jnp.argsort(composite)
    key_s, x_s, y_s, pid_s = key[order], x[order], y[order], pid[order]
    if vid is None:
        vid_s = order.astype(jnp.int32)
    else:
        vid_s = jnp.asarray(vid, jnp.int32)[order]

    counts = jnp.bincount(pid, length=p_total)
    if n_pad is None:
        n_pad = int(max(int(counts.max()), 1))
        n_pad = int(np.ceil(n_pad / 128) * 128)
    if m_pad is None:
        m_pad = n_pad  # safe upper bound; compacted below

    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    col = jnp.arange(n) - starts[pid_s]

    sentinel = jnp.uint32(key_spec.sentinel)
    key_g = jnp.full((p_total, n_pad), sentinel, jnp.uint32)
    x_g = jnp.full((p_total, n_pad), PAD_COORD, jnp.float32)
    y_g = jnp.full((p_total, n_pad), PAD_COORD, jnp.float32)
    vid_g = jnp.full((p_total, n_pad), -1, jnp.int32)
    key_g = key_g.at[pid_s, col].set(key_s)
    x_g = x_g.at[pid_s, col].set(x_s)
    y_g = y_g.at[pid_s, col].set(y_s)
    vid_g = vid_g.at[pid_s, col].set(vid_s)

    fit = fit_partitions(key_g, counts.astype(jnp.int32), eps=eps,
                         m_pad=m_pad, radix_bits=radix_bits)
    if bool(jnp.any(fit["overflow"])):
        raise RuntimeError("spline knot capacity exceeded; raise m_pad")

    # Compact knot arrays to the observed maximum (keeps query VMEM small).
    max_knots = int(jnp.max(fit["n_knots"]))
    m_eff = int(np.ceil(max(max_knots, 2) / 128) * 128)
    m_eff = min(m_eff, m_pad)

    max_run = int(jnp.max(fit["max_run"]))
    probe = probe_for(eps, max_run, n_pad)

    return LearnedSpatialIndex(
        key=key_g, x=x_g, y=y_g, vid=vid_g,
        count=counts.astype(jnp.int32),
        knot_keys=fit["knot_keys"][:, :m_eff],
        knot_pos=fit["knot_pos"][:, :m_eff],
        n_knots=fit["n_knots"],
        radix_table=fit["radix_table"],
        radix_kmin=fit["radix_kmin"],
        radix_scale=fit["radix_scale"],
        part_bounds=jnp.asarray(partitioner.partition_bounds()),
        delta_key=jnp.full((p_total, delta_cap), sentinel, jnp.uint32),
        delta_x=jnp.full((p_total, delta_cap), PAD_COORD, jnp.float32),
        delta_y=jnp.full((p_total, delta_cap), PAD_COORD, jnp.float32),
        delta_vid=jnp.full((p_total, delta_cap), -1, jnp.int32),
        delta_count=jnp.zeros((p_total,), jnp.int32),
        dead=jnp.zeros((p_total,), jnp.int32),
        max_run=fit["max_run"].astype(jnp.int32),
        refit_gen=jnp.zeros((p_total,), jnp.int32),
        eps=eps, radix_bits=radix_bits, probe=probe, key_spec=key_spec,
        overflow_pid=p_total - 1,
    )


@partial(jax.jit, static_argnames=("eps", "m_pad", "radix_bits"))
def fit_partitions(key_g, counts, *, eps: int, m_pad: int, radix_bits: int):
    """vmap'd per-partition spline + radix build (the mapPartitions step)."""
    p_total, n_pad = key_g.shape
    valid = jnp.arange(n_pad)[None, :] < counts[:, None]
    keys_f = K.keys_to_f32(key_g)
    keys_f = jnp.where(valid, keys_f, jnp.float32(3.0e38))

    def one(kf, v):
        sp = S.build_spline(kf, v, eps=eps, m_pad=m_pad)
        rx = R.build_radix(sp["knot_keys"], sp["n_knots"], bits=radix_bits)
        return {
            "knot_keys": sp["knot_keys"], "knot_pos": sp["knot_pos"],
            "n_knots": sp["n_knots"], "max_run": sp["max_run"],
            "overflow": sp["overflow"], "radix_table": rx["table"],
            "radix_kmin": rx["kmin"], "radix_scale": rx["scale"],
        }

    return jax.vmap(one)(keys_f, valid)
