"""Spatial-aware partitioners (paper §3.1, Algorithm 1).

Five strategies, built over a ~1% uniform sample on the driver (the paper:
"the master node must maintain all partitions' properties"): fixed grid,
adaptive grid, Quadtree leaves, KD-tree leaves, STR R-tree leaves. Leaf
boxes = "grids"; objects matching no grid go to the OVERFLOW grid with
id == len(grids) (the paper's novel overflow-grid concept — required for
bottom-up R-trees whose sampled leaves need not cover space).

The fitted partitioner is tiny host state (list of boxes); point->grid
assignment is vectorized JAX (core/build.py), replacing Spark's per-object
loop with a masked argmax — same first-match semantics as Algorithm 1.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

Box = Tuple[float, float, float, float]  # xl, yl, xh, yh


@dataclasses.dataclass
class Partitioner:
    """Fitted global index: leaf boxes + overflow grid."""

    kind: str
    boxes: np.ndarray          # (G, 4) float32, [xl, yl, xh, yh]
    bounds: Box                # overall data bounds (overflow grid box)

    @property
    def num_grids(self) -> int:
        return int(self.boxes.shape[0])

    @property
    def num_partitions(self) -> int:
        return self.num_grids + 1  # + overflow

    def partition_bounds(self) -> np.ndarray:
        """(G+1, 4) — per-partition boxes; overflow = data bounds."""
        ob = np.asarray(self.bounds, np.float32)[None, :]
        return np.concatenate([self.boxes.astype(np.float32), ob], axis=0)


def _sample(x, y, rate: float, seed: int, min_n: int = 256):
    n = x.shape[0]
    m = max(min(n, min_n), int(n * rate))
    rng = np.random.default_rng(seed)
    idx = rng.choice(n, size=m, replace=n < m)
    return x[idx], y[idx]


def _bounds(x, y) -> Box:
    pad = 1e-6
    dx = max(float(x.max() - x.min()), 1e-12) * pad
    dy = max(float(y.max() - y.min()), 1e-12) * pad
    return (float(x.min()), float(y.min()),
            float(x.max()) + dx, float(y.max()) + dy)


def fixed_grid(x, y, num_partitions: int, **_) -> Partitioner:
    """g x g uniform tiling of the data bounds."""
    b = _bounds(x, y)
    g = max(int(np.sqrt(num_partitions)), 1)
    xs = np.linspace(b[0], b[2], g + 1)
    ys = np.linspace(b[1], b[3], g + 1)
    boxes = [(xs[i], ys[j], xs[i + 1], ys[j + 1])
             for i in range(g) for j in range(g)]
    return Partitioner("fixed", np.asarray(boxes, np.float32), b)


def adaptive_grid(x, y, num_partitions: int, sample_rate=0.01, seed=0,
                  **_) -> Partitioner:
    """Equi-depth columns in x, equi-depth rows in y per column."""
    sx, sy = _sample(x, y, sample_rate, seed)
    b = _bounds(x, y)
    g = max(int(np.sqrt(num_partitions)), 1)
    xq = np.quantile(sx, np.linspace(0, 1, g + 1))
    xq[0], xq[-1] = b[0], b[2]
    boxes = []
    for i in range(g):
        m = (sx >= xq[i]) & (sx <= xq[i + 1])
        col = sy[m] if m.sum() > 1 else sy
        yq = np.quantile(col, np.linspace(0, 1, g + 1))
        yq[0], yq[-1] = b[1], b[3]
        yq = np.maximum.accumulate(yq)
        for j in range(g):
            boxes.append((xq[i], yq[j], xq[i + 1], yq[j + 1]))
    return Partitioner("adaptive", np.asarray(boxes, np.float32), b)


def kdtree(x, y, num_partitions: int, sample_rate=0.01, seed=0,
           **_) -> Partitioner:
    """Median-split KD-tree leaves over the sample (paper's default)."""
    sx, sy = _sample(x, y, sample_rate, seed)
    b = _bounds(x, y)
    boxes: List[Box] = []

    def split(ix, box, depth, target):
        if target <= 1 or len(ix) <= 1:
            boxes.append(box)
            return
        if depth % 2 == 0:
            med = float(np.median(sx[ix]))
            med = min(max(med, box[0]), box[2])
            l = ix[sx[ix] <= med]
            r = ix[sx[ix] > med]
            b1 = (box[0], box[1], med, box[3])
            b2 = (med, box[1], box[2], box[3])
        else:
            med = float(np.median(sy[ix]))
            med = min(max(med, box[1]), box[3])
            l = ix[sy[ix] <= med]
            r = ix[sy[ix] > med]
            b1 = (box[0], box[1], box[2], med)
            b2 = (box[0], med, box[2], box[3])
        split(l, b1, depth + 1, target // 2)
        split(r, b2, depth + 1, target - target // 2)

    split(np.arange(len(sx)), b, 0, max(num_partitions, 1))
    return Partitioner("kdtree", np.asarray(boxes, np.float32), b)


def quadtree(x, y, num_partitions: int, sample_rate=0.01, seed=0,
             **_) -> Partitioner:
    """Quadtree leaves: recursively 4-split cells holding too many samples."""
    sx, sy = _sample(x, y, sample_rate, seed)
    b = _bounds(x, y)
    cap = max(len(sx) // max(num_partitions, 1), 1)
    boxes: List[Box] = []

    def rec(ix, box, depth):
        if len(ix) <= cap or depth > 12:
            boxes.append(box)
            return
        mx = 0.5 * (box[0] + box[2])
        my = 0.5 * (box[1] + box[3])
        quads = [(box[0], box[1], mx, my), (mx, box[1], box[2], my),
                 (box[0], my, mx, box[3]), (mx, my, box[2], box[3])]
        for q in quads:
            m = ((sx[ix] >= q[0]) & (sx[ix] < q[2]) &
                 (sy[ix] >= q[1]) & (sy[ix] < q[3]))
            rec(ix[m], q, depth + 1)

    rec(np.arange(len(sx)), b, 0)
    return Partitioner("quadtree", np.asarray(boxes, np.float32), b)


def rtree_str(x, y, num_partitions: int, sample_rate=0.01, seed=0,
              **_) -> Partitioner:
    """Sort-Tile-Recursive R-tree LEAVES over the sample.

    Leaf MBRs bound only the sample, so unseen points may fall outside every
    leaf -> overflow grid (paper §3.1). This is the partitioner whose
    existence motivates the overflow concept.
    """
    sx, sy = _sample(x, y, sample_rate, seed)
    b = _bounds(x, y)
    p = max(num_partitions, 1)
    s = max(int(np.ceil(np.sqrt(p))), 1)
    order = np.argsort(sx, kind="stable")
    sx, sy = sx[order], sy[order]
    n = len(sx)
    per_slice = int(np.ceil(n / s))
    boxes: List[Box] = []
    for i in range(0, n, per_slice):
        cx, cy = sx[i:i + per_slice], sy[i:i + per_slice]
        o2 = np.argsort(cy, kind="stable")
        cx, cy = cx[o2], cy[o2]
        per_tile = max(int(np.ceil(len(cx) / s)), 1)
        for j in range(0, len(cx), per_tile):
            tx, ty = cx[j:j + per_tile], cy[j:j + per_tile]
            if len(tx) == 0:
                continue
            boxes.append((float(tx.min()), float(ty.min()),
                          float(tx.max()), float(ty.max())))
    return Partitioner("rtree", np.asarray(boxes, np.float32), b)


STRATEGIES = {
    "fixed": fixed_grid,       # LiLIS-F
    "adaptive": adaptive_grid, # LiLIS-A
    "quadtree": quadtree,      # LiLIS-Q
    "kdtree": kdtree,          # LiLIS-K (paper default)
    "rtree": rtree_str,        # LiLIS-R
}


def fit(kind: str, x, y, num_partitions: int, sample_rate: float = 0.01,
        seed: int = 0) -> Partitioner:
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    return STRATEGIES[kind](x, y, num_partitions, sample_rate=sample_rate,
                            seed=seed)
