"""Error-bounded greedy spline (paper §3.2, RadixSpline / Neumann-Michel).

Given keys sorted ascending, fit a piecewise-linear spline S with
``|S(key_i) - pos_i| <= eps`` for the FIRST occurrence position of every
distinct key. Built in ONE sequential pass (``jax.lax.scan``) — the same
one-pass property the paper claims for its O(N log N + N) build (sort +
pass); the scan runs per-partition in parallel under vmap/shard_map,
mirroring Spark's ``mapPartitions`` with no shuffle.

Duplicate keys: like RadixSpline we fit the CDF over DISTINCT keys
(first-occurrence rank). A query for any key k then satisfies
``|S(k) - lower_bound(k)| <= eps + max_run`` where max_run is the longest
run of equal keys (a run displaces the rank of the next distinct key).
The build returns max_run so the probe window is chosen to keep every
query EXACT (DESIGN.md §2 "fixed shapes, masked compute").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG = jnp.float32(-3.4e38)
POS = jnp.float32(3.4e38)


@partial(jax.jit, static_argnames=("m_pad", "eps"))
def build_spline(keys_f32, valid, *, eps: int, m_pad: int):
    """Fit the greedy corridor spline.

    Args:
      keys_f32: (N,) float32 keys, sorted ascending; padding entries must be
        at the end and marked invalid.
      valid:    (N,) bool.
      eps:      position error bound (paper default 32).
      m_pad:    knot capacity (static). Worst case needs one knot per
        distinct key; callers size this and tests assert no overflow.

    Returns dict with:
      knot_keys: (m_pad,) f32, padded with +POS
      knot_pos:  (m_pad,) f32
      n_knots:   () int32
      max_run:   () int32  longest duplicate-key run
      overflow:  () bool   True if m_pad was exceeded (spline invalid)
    """
    n = keys_f32.shape[0]
    pos = jnp.arange(n, dtype=jnp.float32)
    prev = jnp.concatenate([jnp.full((1,), -1.0, jnp.float32), keys_f32[:-1]])
    first_occ = valid & (keys_f32 != prev)

    epsf = jnp.float32(eps)

    def emit(knots_k, knots_p, cnt, k, p):
        knots_k = jax.lax.dynamic_update_index_in_dim(
            knots_k, k, jnp.minimum(cnt, m_pad - 1), 0)
        knots_p = jax.lax.dynamic_update_index_in_dim(
            knots_p, p, jnp.minimum(cnt, m_pad - 1), 0)
        return knots_k, knots_p, cnt + 1

    # The scan carries ONLY scalars and streams emitted knots out as
    # per-step ys, compacted into the (m_pad,) arrays by one scatter
    # afterwards. (Carrying the knot buffers through per-step lax.cond
    # branches forced an O(m_pad) carry copy per element — an O(N^2)
    # build that contradicted the paper's one-pass claim and tripped the
    # build-scaling test on every runner.)
    def step(carry, inp):
        kk, kp, lo, hi, px, pp, cnt, started = carry
        x, y, use = inp
        # corridor slopes vs the current knot (garbage when ~started or
        # dx == 0; masked out by the selects below)
        dx = x - kk
        s_lo = (y - epsf - kp) / dx
        s_hi = (y + epsf - kp) / dx
        inside = (s_lo <= hi) & (s_hi >= lo)
        is_first = use & ~started
        new_knot = use & started & ~inside
        tighten = use & started & inside
        # corridor restarted from the previous point (new_knot case)
        dx2 = x - px
        lo2 = (y - epsf - pp) / dx2
        hi2 = (y + epsf - pp) / dx2
        kk2 = jnp.where(is_first, x, jnp.where(new_knot, px, kk))
        kp2 = jnp.where(is_first, y, jnp.where(new_knot, pp, kp))
        lo_n = jnp.where(is_first, NEG,
                         jnp.where(new_knot, lo2,
                                   jnp.where(tighten,
                                             jnp.maximum(lo, s_lo), lo)))
        hi_n = jnp.where(is_first, POS,
                         jnp.where(new_knot, hi2,
                                   jnp.where(tighten,
                                             jnp.minimum(hi, s_hi), hi)))
        emit_f = is_first | new_knot
        out = (emit_f, jnp.where(is_first, x, px),
               jnp.where(is_first, y, pp))
        cnt2 = cnt + emit_f.astype(jnp.int32)
        carry2 = (kk2, kp2, lo_n, hi_n, jnp.where(use, x, px),
                  jnp.where(use, y, pp), cnt2, started | use)
        return carry2, out

    init = (jnp.float32(0), jnp.float32(0), NEG, POS,
            jnp.float32(0), jnp.float32(0), jnp.int32(0),
            jnp.bool_(False))
    (kk, kp, lo, hi, px, pp, cnt, started), (emit_f, emit_k, emit_p) = (
        jax.lax.scan(step, init, (keys_f32, pos, first_occ)))

    # Compact the emitted stream into the knot arrays (order-preserving;
    # entries beyond m_pad clamp to the last slot exactly like the
    # sequential emit() did — they only occur when overflow is flagged).
    slot = jnp.minimum(jnp.cumsum(emit_f.astype(jnp.int32)) - 1,
                       m_pad - 1)
    slot = jnp.where(emit_f, slot, m_pad)          # dropped by scatter
    knots_k = jnp.full((m_pad,), POS, jnp.float32).at[slot].set(
        emit_k, mode="drop")
    knots_p = jnp.zeros((m_pad,), jnp.float32).at[slot].set(
        emit_p, mode="drop")

    # Close the spline: last seen point becomes the final knot (unless it
    # already is the only knot == first point with cnt==1 and px==kk).
    need_tail = started & ((cnt == 1) | (px != kk))
    knots_k, knots_p, cnt = jax.tree_util.tree_map(
        lambda a, b: jnp.where(need_tail, a, b),
        emit(knots_k, knots_p, cnt, px, pp), (knots_k, knots_p, cnt))

    # Degenerate single-distinct-key partition: add a synthetic second knot
    # so interpolation never divides by zero.
    single = started & (cnt == 1)
    knots_k, knots_p, cnt = jax.tree_util.tree_map(
        lambda a, b: jnp.where(single, a, b),
        emit(knots_k, knots_p, cnt, kk + 1.0, kp), (knots_k, knots_p, cnt))

    # Longest run of equal keys among valid entries.
    run_start = first_occ
    run_id = jnp.cumsum(run_start.astype(jnp.int32)) - 1
    run_id = jnp.where(valid, run_id, n)  # padding into a junk segment
    ones = valid.astype(jnp.int32)
    run_len = jax.ops.segment_sum(ones, run_id, num_segments=n + 1)[:-1]
    max_run = jnp.max(run_len)

    return {
        "knot_keys": knots_k,
        "knot_pos": knots_p,
        "n_knots": jnp.minimum(cnt, m_pad),
        "max_run": max_run.astype(jnp.int32),
        "overflow": cnt > m_pad,
    }


def spline_predict(knot_keys, knot_pos, n_knots, query_f32):
    """Interpolate predicted first-occurrence rank of ``query_f32``.

    Vectorized over arbitrary query shape. Uses full binary search over the
    knot array (O(log m_pad)); the radix table (radix.py) narrows this and
    the Pallas kernel exploits the narrowing.
    """
    m_pad = knot_keys.shape[0]
    # knots are padded with +POS so searchsorted stays in range.
    seg = jnp.searchsorted(knot_keys, query_f32, side="right") - 1
    seg = jnp.clip(seg, 0, jnp.maximum(n_knots - 2, 0))
    k0 = knot_keys[seg]
    k1 = knot_keys[seg + 1]
    p0 = knot_pos[seg]
    p1 = knot_pos[seg + 1]
    t = (query_f32 - k0) / jnp.maximum(k1 - k0, 1e-30)
    t = jnp.clip(t, 0.0, 1.0)
    return p0 + t * (p1 - p0)
