"""Error-bounded greedy spline (paper §3.2, RadixSpline / Neumann-Michel).

Given keys sorted ascending, fit a piecewise-linear spline S with
``|S(key_i) - pos_i| <= eps`` for the FIRST occurrence position of every
distinct key. Built in ONE sequential pass (``jax.lax.scan``) — the same
one-pass property the paper claims for its O(N log N + N) build (sort +
pass); the scan runs per-partition in parallel under vmap/shard_map,
mirroring Spark's ``mapPartitions`` with no shuffle.

Duplicate keys: like RadixSpline we fit the CDF over DISTINCT keys
(first-occurrence rank). A query for any key k then satisfies
``|S(k) - lower_bound(k)| <= eps + max_run`` where max_run is the longest
run of equal keys (a run displaces the rank of the next distinct key).
The build returns max_run so the probe window is chosen to keep every
query EXACT (DESIGN.md §2 "fixed shapes, masked compute").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG = jnp.float32(-3.4e38)
POS = jnp.float32(3.4e38)


@partial(jax.jit, static_argnames=("m_pad", "eps"))
def build_spline(keys_f32, valid, *, eps: int, m_pad: int):
    """Fit the greedy corridor spline.

    Args:
      keys_f32: (N,) float32 keys, sorted ascending; padding entries must be
        at the end and marked invalid.
      valid:    (N,) bool.
      eps:      position error bound (paper default 32).
      m_pad:    knot capacity (static). Worst case needs one knot per
        distinct key; callers size this and tests assert no overflow.

    Returns dict with:
      knot_keys: (m_pad,) f32, padded with +POS
      knot_pos:  (m_pad,) f32
      n_knots:   () int32
      max_run:   () int32  longest duplicate-key run
      overflow:  () bool   True if m_pad was exceeded (spline invalid)
    """
    n = keys_f32.shape[0]
    pos = jnp.arange(n, dtype=jnp.float32)
    prev = jnp.concatenate([jnp.full((1,), -1.0, jnp.float32), keys_f32[:-1]])
    first_occ = valid & (keys_f32 != prev)

    epsf = jnp.float32(eps)

    def emit(knots_k, knots_p, cnt, k, p):
        knots_k = jax.lax.dynamic_update_index_in_dim(
            knots_k, k, jnp.minimum(cnt, m_pad - 1), 0)
        knots_p = jax.lax.dynamic_update_index_in_dim(
            knots_p, p, jnp.minimum(cnt, m_pad - 1), 0)
        return knots_k, knots_p, cnt + 1

    def step(carry, inp):
        (kk, kp, lo, hi, px, pp, cnt, knots_k, knots_p, started) = carry
        x, y, use = inp

        def do(carry):
            kk, kp, lo, hi, px, pp, cnt, knots_k, knots_p, started = carry

            def first(_):
                kk2, kp2 = x, y
                knots_k2, knots_p2, cnt2 = emit(knots_k, knots_p, cnt, x, y)
                return (kk2, kp2, NEG, POS, x, y, cnt2, knots_k2, knots_p2,
                        jnp.bool_(True))

            def rest(_):
                dx = x - kk
                s_lo = (y - epsf - kp) / dx
                s_hi = (y + epsf - kp) / dx
                inside = (s_lo <= hi) & (s_hi >= lo)

                def tighten(_):
                    return (kk, kp, jnp.maximum(lo, s_lo),
                            jnp.minimum(hi, s_hi), x, y, cnt,
                            knots_k, knots_p, started)

                def new_knot(_):
                    # Previous point becomes a knot; restart corridor from it.
                    knots_k2, knots_p2, cnt2 = emit(knots_k, knots_p, cnt,
                                                    px, pp)
                    dx2 = x - px
                    lo2 = (y - epsf - pp) / dx2
                    hi2 = (y + epsf - pp) / dx2
                    return (px, pp, lo2, hi2, x, y, cnt2,
                            knots_k2, knots_p2, started)

                return jax.lax.cond(inside, tighten, new_knot, None)

            return jax.lax.cond(started, rest, first, None)

        carry2 = jax.lax.cond(use, do, lambda c: c, carry)
        return carry2, None

    knots_k0 = jnp.full((m_pad,), POS, jnp.float32)
    knots_p0 = jnp.zeros((m_pad,), jnp.float32)
    init = (jnp.float32(0), jnp.float32(0), NEG, POS,
            jnp.float32(0), jnp.float32(0), jnp.int32(0),
            knots_k0, knots_p0, jnp.bool_(False))
    (kk, kp, lo, hi, px, pp, cnt, knots_k, knots_p, started), _ = (
        jax.lax.scan(step, init, (keys_f32, pos, first_occ)))

    # Close the spline: last seen point becomes the final knot (unless it
    # already is the only knot == first point with cnt==1 and px==kk).
    need_tail = started & ((cnt == 1) | (px != kk))
    knots_k, knots_p, cnt = jax.tree_util.tree_map(
        lambda a, b: jnp.where(need_tail, a, b),
        emit(knots_k, knots_p, cnt, px, pp), (knots_k, knots_p, cnt))

    # Degenerate single-distinct-key partition: add a synthetic second knot
    # so interpolation never divides by zero.
    single = started & (cnt == 1)
    knots_k, knots_p, cnt = jax.tree_util.tree_map(
        lambda a, b: jnp.where(single, a, b),
        emit(knots_k, knots_p, cnt, kk + 1.0, kp), (knots_k, knots_p, cnt))

    # Longest run of equal keys among valid entries.
    run_start = first_occ
    run_id = jnp.cumsum(run_start.astype(jnp.int32)) - 1
    run_id = jnp.where(valid, run_id, n)  # padding into a junk segment
    ones = valid.astype(jnp.int32)
    run_len = jax.ops.segment_sum(ones, run_id, num_segments=n + 1)[:-1]
    max_run = jnp.max(run_len)

    return {
        "knot_keys": knots_k,
        "knot_pos": knots_p,
        "n_knots": jnp.minimum(cnt, m_pad),
        "max_run": max_run.astype(jnp.int32),
        "overflow": cnt > m_pad,
    }


def spline_predict(knot_keys, knot_pos, n_knots, query_f32):
    """Interpolate predicted first-occurrence rank of ``query_f32``.

    Vectorized over arbitrary query shape. Uses full binary search over the
    knot array (O(log m_pad)); the radix table (radix.py) narrows this and
    the Pallas kernel exploits the narrowing.
    """
    m_pad = knot_keys.shape[0]
    # knots are padded with +POS so searchsorted stays in range.
    seg = jnp.searchsorted(knot_keys, query_f32, side="right") - 1
    seg = jnp.clip(seg, 0, jnp.maximum(n_knots - 2, 0))
    k0 = knot_keys[seg]
    k1 = knot_keys[seg + 1]
    p0 = knot_pos[seg]
    p1 = knot_pos[seg + 1]
    t = (query_f32 - k0) / jnp.maximum(k1 - k0, 1e-30)
    t = jnp.clip(t, 0.0, 1.0)
    return p0 + t * (p1 - p0)
