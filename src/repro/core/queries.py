"""Local (per-partition) query algorithms (paper §4), vectorized.

TPU adaptation (DESIGN.md §2): the paper's per-query control flow becomes
batched fixed-shape masked compute. Each primitive below operates on ONE
partition's arrays and a BATCH of queries; engine.py vmaps over partitions
and adds the global (partitioner) pruning + collectives.

Exactness contract: ``probe`` (static, chosen at build from eps + the
longest duplicate run) guarantees the true lower bound lies strictly
inside every probe window, so windowed counting reproduces exact
``searchsorted`` semantics — property-tested against oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import keys as K
from repro.core import radix as R

F32_BIG = jnp.float32(3.0e38)


# ---------------------------------------------------------------------------
# learned search primitive (paper Fig. 3: radix -> spline -> bounded probe)
# ---------------------------------------------------------------------------

def learned_lower_bound(part, qkf, *, radix_bits: int, probe: int):
    """Exact lower_bound (first idx with key >= q) for a batch of queries.

    part: dict with keys_f (n_pad,), knot_keys (m,), knot_pos (m,),
          n_knots (), radix_table (2^b+2,), radix_kmin (), radix_scale (),
          count ().
    qkf:  (Q,) float32 query keys.
    Returns (Q,) int32 positions in [0, count].
    """
    n_pad = part["keys_f"].shape[0]
    radix = {"table": part["radix_table"], "kmin": part["radix_kmin"],
             "scale": part["radix_scale"]}
    lo, hi = R.radix_locate(radix, qkf, part["n_knots"], bits=radix_bits)
    seg = R.windowed_segment_search(part["knot_keys"], qkf, lo, hi)
    k0 = part["knot_keys"][seg]
    k1 = part["knot_keys"][jnp.minimum(seg + 1, part["knot_keys"].shape[0] - 1)]
    p0 = part["knot_pos"][seg]
    p1 = part["knot_pos"][jnp.minimum(seg + 1, part["knot_pos"].shape[0] - 1)]
    t = jnp.clip((qkf - k0) / jnp.maximum(k1 - k0, 1e-30), 0.0, 1.0)
    phat = p0 + t * (p1 - p0)

    start = jnp.clip(jnp.round(phat).astype(jnp.int32) - probe // 2,
                     0, n_pad - probe)

    def one(s, q):
        win = jax.lax.dynamic_slice(part["keys_f"], (s,), (probe,))
        return s + jnp.sum((win < q).astype(jnp.int32))

    pos = jax.vmap(one)(start, qkf)
    return jnp.minimum(pos, part["count"])


def learned_bounds(part, klo_f, khi_f, *, radix_bits: int, probe: int):
    """[s, e) covering all keys in [klo, khi] (integer-key semantics)."""
    s = learned_lower_bound(part, klo_f, radix_bits=radix_bits, probe=probe)
    e = learned_lower_bound(part, khi_f + 1.0, radix_bits=radix_bits,
                            probe=probe)
    return s, e


# ---------------------------------------------------------------------------
# range query (paper §4.2)
# (the point query — paper Alg. 3 — lives in the staged pipeline now:
#  lower_bound_at lookup + Backend.point_scan window-equality probe)
# ---------------------------------------------------------------------------

def range_count_partition(part, rects, klo_f, khi_f, *, radix_bits: int,
                          probe: int, active=None):
    """Exact in-rect counts (Q,) for one partition.

    Uses the learned [s, e) key-interval as position mask (the paper's
    filter phase) + coordinate refine. ``active`` (Q,) optionally masks
    queries whose global filter already rejected this partition.
    """
    n_pad = part["keys_f"].shape[0]
    s, e = learned_bounds(part, klo_f, khi_f, radix_bits=radix_bits,
                          probe=probe)
    posn = jnp.arange(n_pad, dtype=jnp.int32)
    valid = posn < part["count"]
    inpos = (posn[None, :] >= s[:, None]) & (posn[None, :] < e[:, None])
    xl, yl, xh, yh = (rects[:, 0:1], rects[:, 1:2], rects[:, 2:3],
                      rects[:, 3:4])
    inrect = ((part["x"][None, :] >= xl) & (part["x"][None, :] <= xh) &
              (part["y"][None, :] >= yl) & (part["y"][None, :] <= yh))
    m = valid[None, :] & inpos & inrect
    if active is not None:
        m = m & active[:, None]
    return jnp.sum(m.astype(jnp.int32), axis=1), m


def range_window_partition(part, rects, klo_f, khi_f, *, radix_bits: int,
                           probe: int, cap: int, active=None):
    """Windowed fast path: gather only [s, s+cap) candidates per query.

    Returns (counts (Q,), vids (Q, cap) int32 padded -1, ok (Q,) bool —
    False when the learned interval exceeded ``cap`` and the caller must
    fall back / re-run with a larger cap). This is the path whose work is
    proportional to the LEARNED interval, not the partition size — the
    measurable learned-index advantage on CPU benchmarks and the block-skip
    structure the Pallas kernel exploits on TPU.
    """
    n_pad = part["keys_f"].shape[0]
    s, e = learned_bounds(part, klo_f, khi_f, radix_bits=radix_bits,
                          probe=probe)
    ok = (e - s) <= cap
    start = jnp.clip(s, 0, jnp.maximum(n_pad - cap, 0))

    def one(s0, st, en, rect):
        wx = jax.lax.dynamic_slice(part["x"], (s0,), (cap,))
        wy = jax.lax.dynamic_slice(part["y"], (s0,), (cap,))
        wv = jax.lax.dynamic_slice(part["vid"], (s0,), (cap,))
        posn = s0 + jnp.arange(cap, dtype=jnp.int32)
        m = ((posn >= st) & (posn < en) & (posn < part["count"]) &
             (wx >= rect[0]) & (wx <= rect[2]) &
             (wy >= rect[1]) & (wy <= rect[3]))
        return jnp.sum(m.astype(jnp.int32)), jnp.where(m, wv, -1)

    counts, vids = jax.vmap(one)(start, s, e, rects)
    if active is not None:
        counts = jnp.where(active, counts, 0)
        vids = jnp.where(active[:, None], vids, -1)
        ok = ok | ~active
    return counts, vids, ok


# ---------------------------------------------------------------------------
# query-centric primitives: operate on (Q, C) CANDIDATE partitions only
# (phase-1 pruning makes the work proportional to candidates, not to the
# total partition count — the paper's "at most one/few partitions per
# query" property).
# ---------------------------------------------------------------------------

def lower_bound_at(parts, pid, qkf, *, radix_bits: int, probe: int):
    """Exact lower_bound against partition ``pid`` per element.

    parts: full engine dict ((P, ...) arrays); pid, qkf: (...,) matching
    shapes. Vectorized with vmap; each element gathers only that
    partition's knot row + probe window. The compacted knot rows are
    small (<= a few hundred), so a full branchless compare-count beats
    gathering the (2^b + 2)-entry radix row — the radix table pays off
    only in the partition-resident Pallas kernel (kernels/spline_search)
    where it is already in VMEM; documented in DESIGN.md §5.
    """
    del radix_bits
    n_pad = parts["keys_f"].shape[1]
    m = parts["knot_keys"].shape[1]

    def one(p, q):
        krow = jax.lax.dynamic_slice(parts["knot_keys"], (p, 0),
                                     (1, m))[0]
        prow = jax.lax.dynamic_slice(parts["knot_pos"], (p, 0), (1, m))[0]
        cnt = parts["count"][p]
        # branchless segment locate over the whole (padded +inf) row
        succ = jnp.sum((krow < q).astype(jnp.int32))
        seg = jnp.clip(succ - 1, 0, m - 2)
        k0 = krow[seg]
        k1 = krow[seg + 1]
        p0 = prow[seg]
        p1 = prow[seg + 1]
        t = jnp.clip((q - k0) / jnp.maximum(k1 - k0, 1e-30), 0.0, 1.0)
        phat = p0 + t * (p1 - p0)
        start = jnp.clip(jnp.round(phat).astype(jnp.int32) - probe // 2,
                         0, n_pad - probe)
        win = jax.lax.dynamic_slice(parts["keys_f"], (p, start),
                                    (1, probe))[0]
        return jnp.minimum(start + jnp.sum((win < q).astype(jnp.int32)),
                           cnt)

    flat_p = pid.reshape(-1)
    flat_q = qkf.reshape(-1)
    out = jax.vmap(one)(flat_p, flat_q)
    return out.reshape(pid.shape)


def bounds_on_rows(parts, pid, qk, *, probe: int):
    """lower_bound for MULTIPLE keys per candidate partition, sharing
    one knot/pos row gather per (query, candidate).

    pid: (Q, C); qk: (Q, C, T) float32 keys. Returns (Q, C, T) int32.
    """
    qn, c, t = qk.shape
    n_pad = parts["keys_f"].shape[1]
    m = parts["knot_keys"].shape[1]

    def one(p, qs):                       # qs: (T,)
        krow = jax.lax.dynamic_slice(parts["knot_keys"], (p, 0),
                                     (1, m))[0]
        prow = jax.lax.dynamic_slice(parts["knot_pos"], (p, 0),
                                     (1, m))[0]
        cnt = parts["count"][p]
        succ = jnp.sum((krow[None, :] < qs[:, None]).astype(jnp.int32),
                       axis=1)
        seg = jnp.clip(succ - 1, 0, m - 2)
        k0 = krow[seg]
        k1 = krow[seg + 1]
        p0 = prow[seg]
        p1 = prow[seg + 1]
        tt = jnp.clip((qs - k0) / jnp.maximum(k1 - k0, 1e-30), 0.0, 1.0)
        phat = p0 + tt * (p1 - p0)
        start = jnp.clip(phat.astype(jnp.int32) - probe // 2, 0,
                         n_pad - probe)

        def probe_one(s0, q):
            win = jax.lax.dynamic_slice(parts["keys_f"], (p, s0),
                                        (1, probe))[0]
            return s0 + jnp.sum((win < q).astype(jnp.int32))

        pos = jax.vmap(probe_one)(start, qs)
        return jnp.minimum(pos, cnt)

    out = jax.vmap(one)(pid.reshape(-1),
                        qk.reshape(-1, t))
    return out.reshape(qn, c, t)


def _window_intervals(parts, bounds, pid, valid, rects, spec, *,
                      cap: int, probe: int, z_depth: int):
    """Shared phase-1.5 of the windowed gathers: clip each query rect to
    its candidate boxes, z-decompose, and compute the learned [s, e)
    interval per disjoint subinterval.

    Returns (rect_e (Q, C, 4), s, e, st (Q, C, S), ok (Q, C),
    act_s (Q, C, S)) — the gather coordinates every windowed variant
    (plain range, fused circle) consumes.
    """
    qn, c = pid.shape
    n_pad = parts["keys_f"].shape[1]
    boxes = bounds  # (Q, C, 4) candidate boxes, looked up by the caller
    rect_e = jnp.broadcast_to(rects[:, None, :], (qn, c, 4))
    xl = jnp.maximum(rect_e[..., 0], boxes[..., 0])
    yl = jnp.maximum(rect_e[..., 1], boxes[..., 1])
    xh = jnp.minimum(rect_e[..., 2], boxes[..., 2])
    yh = jnp.minimum(rect_e[..., 3], boxes[..., 3])
    nonempty = (xl <= xh) & (yl <= yh) & valid
    from repro.core import keys as K
    bx = spec.bounds
    qxl = K.quantize(jnp.where(nonempty, xl, 0.0), bx[0], bx[2],
                     spec.bits_per_dim)
    qyl = K.quantize(jnp.where(nonempty, yl, 0.0), bx[1], bx[3],
                     spec.bits_per_dim)
    qxh = K.quantize(jnp.where(nonempty, xh, 0.0), bx[0], bx[2],
                     spec.bits_per_dim)
    qyh = K.quantize(jnp.where(nonempty, yh, 0.0), bx[1], bx[3],
                     spec.bits_per_dim)
    # z-interval decomposition: (Q, C, S) disjoint subintervals
    zlo, zhi, pv = K.z_split_intervals(qxl, qyl, qxh, qyh, nonempty,
                                       depth=z_depth)
    sN = zlo.shape[-1]
    klo = K.keys_to_f32(zlo)
    khi = K.keys_to_f32(zhi)
    # gather each candidate's knot/pos row ONCE; all 2S bounds reuse it
    qk2 = jnp.concatenate([klo, khi + 1.0], axis=-1)      # (Q, C, 2S)
    pos2 = bounds_on_rows(parts, pid, qk2, probe=probe)
    s = pos2[..., :sN]
    e = pos2[..., sN:]
    e = jnp.where(pv, e, s)
    ok = jnp.all(((e - s) <= cap) | ~pv, axis=-1) | ~nonempty
    st = jnp.clip(s, 0, jnp.maximum(n_pad - cap, 0))
    act_s = pv & nonempty[..., None]
    return rect_e, s, e, st, ok, act_s


def range_window_at(parts, bounds, pid, valid, rects, spec, *,
                    cap: int, radix_bits: int, probe: int,
                    z_depth: int = 2):
    """Windowed range query against candidate partitions.

    pid, valid: (Q, C); rects: (Q, 4). Returns
    (counts (Q, C), vids (Q, C, S*cap), ok (Q, C), wx, wy).
    """
    del radix_bits
    qn, c = pid.shape
    rect_e, s, e, st, ok, act_s = _window_intervals(
        parts, bounds, pid, valid, rects, spec, cap=cap, probe=probe,
        z_depth=z_depth)
    sN = s.shape[-1]
    pid_s = jnp.broadcast_to(pid[..., None], s.shape)

    def gather(p, s0, st_, en, rect, act):
        wx = jax.lax.dynamic_slice(parts["x"], (p, s0), (1, cap))[0]
        wy = jax.lax.dynamic_slice(parts["y"], (p, s0), (1, cap))[0]
        wv = jax.lax.dynamic_slice(parts["vid"], (p, s0), (1, cap))[0]
        posn = s0 + jnp.arange(cap, dtype=jnp.int32)
        mask = ((posn >= st_) & (posn < en) &
                (posn < parts["count"][p]) &
                (wx >= rect[0]) & (wx <= rect[2]) &
                (wy >= rect[1]) & (wy <= rect[3]) & act)
        return (jnp.sum(mask.astype(jnp.int32)),
                jnp.where(mask, wv, -1), wx, wy)

    rect_s = jnp.broadcast_to(rect_e[:, :, None, :], (qn, c, sN, 4))
    cnts, vids, wx, wy = jax.vmap(gather)(
        pid_s.reshape(-1), st.reshape(-1), s.reshape(-1), e.reshape(-1),
        rect_s.reshape(-1, 4), act_s.reshape(-1))
    # subintervals are DISJOINT, so per-candidate counts just add
    return (jnp.sum(cnts.reshape(qn, c, sN), axis=-1),
            vids.reshape(qn, c, sN * cap), ok,
            wx.reshape(qn, c, sN * cap), wy.reshape(qn, c, sN * cap))


def circle_window_at(parts, bounds, pid, valid, rects, circ, spec, *,
                     cap: int, radix_bits: int, probe: int,
                     z_depth: int = 2, materialize: bool = True):
    """Fused circle variant of the windowed gather (paper Remark 2).

    The distance refine runs INSIDE the per-subinterval gather, so the
    caller receives pre-refined in-circle counts (and compacted ids when
    materializing) and the (Q, C, S*cap) wx/wy coordinate planes are
    never materialized. ``rects`` is the circle's MBR; ``circ`` is
    (Q, 3) [cx, cy, r]. Counts are bitwise what the unfused
    gather-then-refine computed (same f32 distance ops on the same
    window slices). Returns (counts (Q, C), vids (Q, C, S*cap) | None,
    ok (Q, C)); vids is None when ``materialize`` is False (the counting
    path never touches the vid plane at all).
    """
    del radix_bits
    qn, c = pid.shape
    rect_e, s, e, st, ok, act_s = _window_intervals(
        parts, bounds, pid, valid, rects, spec, cap=cap, probe=probe,
        z_depth=z_depth)
    sN = s.shape[-1]
    pid_s = jnp.broadcast_to(pid[..., None], s.shape)
    circ_s = jnp.broadcast_to(circ[:, None, None, :], (qn, c, sN, 3))
    rect_s = jnp.broadcast_to(rect_e[:, :, None, :], (qn, c, sN, 4))

    def mask_of(p, s0, st_, en, rect, cc, act, wx, wy):
        posn = s0 + jnp.arange(cap, dtype=jnp.int32)
        dx = wx - cc[0]
        dy = wy - cc[1]
        return ((posn >= st_) & (posn < en) &
                (posn < parts["count"][p]) &
                (wx >= rect[0]) & (wx <= rect[2]) &
                (wy >= rect[1]) & (wy <= rect[3]) & act &
                (dx * dx + dy * dy <= cc[2] * cc[2]))

    if materialize:
        def gather(p, s0, st_, en, rect, cc, act):
            wx = jax.lax.dynamic_slice(parts["x"], (p, s0), (1, cap))[0]
            wy = jax.lax.dynamic_slice(parts["y"], (p, s0), (1, cap))[0]
            wv = jax.lax.dynamic_slice(parts["vid"], (p, s0),
                                       (1, cap))[0]
            m = mask_of(p, s0, st_, en, rect, cc, act, wx, wy)
            return jnp.sum(m.astype(jnp.int32)), jnp.where(m, wv, -1)

        cnts, vids = jax.vmap(gather)(
            pid_s.reshape(-1), st.reshape(-1), s.reshape(-1),
            e.reshape(-1), rect_s.reshape(-1, 4), circ_s.reshape(-1, 3),
            act_s.reshape(-1))
        return (jnp.sum(cnts.reshape(qn, c, sN), axis=-1),
                vids.reshape(qn, c, sN * cap), ok)

    def gather_cnt(p, s0, st_, en, rect, cc, act):
        wx = jax.lax.dynamic_slice(parts["x"], (p, s0), (1, cap))[0]
        wy = jax.lax.dynamic_slice(parts["y"], (p, s0), (1, cap))[0]
        m = mask_of(p, s0, st_, en, rect, cc, act, wx, wy)
        return jnp.sum(m.astype(jnp.int32))

    cnts = jax.vmap(gather_cnt)(
        pid_s.reshape(-1), st.reshape(-1), s.reshape(-1), e.reshape(-1),
        rect_s.reshape(-1, 4), circ_s.reshape(-1, 3), act_s.reshape(-1))
    return jnp.sum(cnts.reshape(qn, c, sN), axis=-1), None, ok


def gather_delta(parts, pid, valid):
    """Gather (Q, C) candidate partitions' delta buffers + live mask.

    Liveness rule: slot < dcount AND vid >= 0 AND candidate valid.
    Every QUERY-CENTRIC delta probe (range/circle windows, kNN
    candidates, join windows) builds on this gather; the partition-
    centric scans apply the same rule per row in
    ``backends.XlaBackend.delta_live`` and the point probe inlines it
    over its lid-gathered rows (local_ops._PointLocal) — change all
    three together. Returns (dx, dy, dvid (Q, C, d_cap),
    live (Q, C, d_cap) bool).
    """
    qn, c = pid.shape
    d_cap = parts["dvid"].shape[1]
    flat = pid.reshape(-1)
    dx = jnp.take(parts["dx"], flat, axis=0).reshape(qn, c, d_cap)
    dy = jnp.take(parts["dy"], flat, axis=0).reshape(qn, c, d_cap)
    dv = jnp.take(parts["dvid"], flat, axis=0).reshape(qn, c, d_cap)
    dcnt = jnp.take(parts["dcount"], flat, axis=0).reshape(qn, c)
    slot = jnp.arange(d_cap, dtype=jnp.int32)
    live = ((slot[None, None, :] < dcnt[..., None]) & (dv >= 0) &
            valid[..., None])
    return dx, dy, dv, live


def delta_window_at(parts, pid, valid, rects, circ=None):
    """Live delta-buffer matches of (Q, C) candidate partitions
    (DESIGN.md §11: the delta probe rides alongside the learned window
    gather; buffers are tiny, so a full masked scan is the whole cost).

    pid, valid: (Q, C) local partition ids + mask; rects: (Q, 4);
    circ: optional (Q, 3) [cx, cy, r] distance refine.
    Returns (counts (Q, C) int32, vids (Q, C, d_cap) int32 padded -1).
    """
    dx, dy, dv, live = gather_delta(parts, pid, valid)
    r = rects[:, None, None, :]
    m = (live & (dx >= r[..., 0]) & (dx <= r[..., 2]) &
         (dy >= r[..., 1]) & (dy <= r[..., 3]))
    if circ is not None:
        cc = circ[:, None, None, :]
        ddx = dx - cc[..., 0]
        ddy = dy - cc[..., 1]
        m = m & (ddx * ddx + ddy * ddy <= cc[..., 2] * cc[..., 2])
    return (jnp.sum(m.astype(jnp.int32), axis=-1),
            jnp.where(m, dv, -1))


# ---------------------------------------------------------------------------
# geometry helpers
# ---------------------------------------------------------------------------

def clip_rect_to_box(rects, box):
    """Intersect (Q, 4) rects with one partition box (4,).

    The morton interval of the CLIPPED rect is dramatically tighter than
    the global rect's interval (the Z-curve detours outside the
    partition are cut off) — the partition-local filter phase works on
    the clipped keys. Empty intersections produce inverted rects whose
    key range is empty after the (klo > khi) guard.
    """
    xl = jnp.maximum(rects[:, 0], box[0])
    yl = jnp.maximum(rects[:, 1], box[1])
    xh = jnp.minimum(rects[:, 2], box[2])
    yh = jnp.minimum(rects[:, 3], box[3])
    return jnp.stack([xl, yl, xh, yh], axis=1)


def clipped_key_range(rects, box, spec):
    """Per-partition (klo_f, khi_f, nonempty) for clipped rects."""
    from repro.core import keys as K
    cl = clip_rect_to_box(rects, box)
    nonempty = (cl[:, 0] <= cl[:, 2]) & (cl[:, 1] <= cl[:, 3])
    safe = jnp.where(nonempty[:, None], cl,
                     jnp.zeros_like(cl))
    klo, khi = K.rect_key_range(safe, spec)
    return (K.keys_to_f32(klo), K.keys_to_f32(khi), nonempty)


def rect_overlaps_box(rects, boxes):
    """(Q, P) — axis-aligned overlap test (global filter phase)."""
    xl, yl, xh, yh = (rects[:, 0:1], rects[:, 1:2], rects[:, 2:3],
                      rects[:, 3:4])
    bxl, byl, bxh, byh = boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3]
    return ((xl <= bxh) & (xh >= bxl) & (yl <= byh) & (yh >= byl))


def point_in_box(qx, qy, boxes):
    """(Q, P) containment of query points in partition boxes."""
    return ((qx[:, None] >= boxes[:, 0]) & (qx[:, None] <= boxes[:, 2]) &
            (qy[:, None] >= boxes[:, 1]) & (qy[:, None] <= boxes[:, 3]))


def box_min_dist2(qx, qy, boxes):
    """(Q, P) squared min distance from points to boxes (kNN pruning)."""
    dx = jnp.maximum(jnp.maximum(boxes[:, 0] - qx[:, None],
                                 qx[:, None] - boxes[:, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(boxes[:, 1] - qy[:, None],
                                 qy[:, None] - boxes[:, 3]), 0.0)
    return dx * dx + dy * dy


def point_in_polygon(px, py, poly, n_edges):
    """Ray-casting parity test. px, py: (N,); poly: (E, 2); n_edges: ().

    Returns (N,) bool. Edges are (poly[i], poly[i+1 mod n]); padding edges
    (i >= n_edges) are skipped.
    """
    e_max = poly.shape[0]

    def body(i, parity):
        x1, y1 = poly[i, 0], poly[i, 1]
        nxt = jnp.where(i + 1 >= n_edges, 0, i + 1)
        x2, y2 = poly[nxt, 0], poly[nxt, 1]
        cond = ((y1 > py) != (y2 > py))
        t = (py - y1) / jnp.where(y2 == y1, 1e-30, y2 - y1)
        xin = x1 + t * (x2 - x1)
        crosses = cond & (px < xin) & (i < n_edges)
        return parity ^ crosses

    return jax.lax.fori_loop(0, e_max, body,
                             jnp.zeros(px.shape, dtype=bool))
