"""1-D key derivation for 2-D spatial points (paper §3.2).

The paper projects (x, y) to a sort key via "either one arbitrary axis or
some aggregated value (e.g., Z-order curve and GeoHash)". We implement:

  * ``morton`` (default) — bit-interleaved Z-order code over quantized
    coordinates. Morton codes are jointly monotone: x1<=x2 and y1<=y2
    implies z(x1,y1) <= z(x2,y2), so the key interval
    [z(rect_lo), z(rect_hi)] covers every point of an axis-aligned rect
    (with false positives that the refine phase removes) — exactly the
    filter+refine contract the paper's range query relies on.
  * ``x`` / ``y`` — single-axis keys.

TPU adaptation notes (DESIGN.md §2): keys are kept at <= 24 total bits so
their float32 image is EXACT (f32 has a 24-bit mantissa); all spline /
radix arithmetic then incurs no key-rounding error. Default is 11 bits per
dimension (22-bit Morton key), leaving 10 bits of headroom for a partition
id in a single uint32 composite sort key (paper's re-partition shuffle is
realized as one global radix sort).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Default geometry of the key space.
DEFAULT_BITS_PER_DIM = 11          # 22-bit morton keys, exact in float32
MAX_BITS_PER_DIM = 12              # 24-bit morton keys, still exact in f32


@dataclasses.dataclass(frozen=True)
class KeySpec:
    """How 2-D points are projected to 1-D sort keys."""

    kind: str = "morton"           # 'morton' | 'x' | 'y'
    bits_per_dim: int = DEFAULT_BITS_PER_DIM
    # Data-space bounds used for quantization: (xlo, ylo, xhi, yhi).
    bounds: Tuple[float, float, float, float] = (0.0, 0.0, 1.0, 1.0)

    @property
    def key_bits(self) -> int:
        if self.kind == "morton":
            return 2 * self.bits_per_dim
        return self.bits_per_dim

    @property
    def sentinel(self) -> int:
        """Padding key, strictly greater than every valid key."""
        return 1 << self.key_bits

    def __post_init__(self):
        if self.kind not in ("morton", "x", "y"):
            raise ValueError(f"unknown key kind {self.kind!r}")
        if self.kind == "morton" and self.bits_per_dim > MAX_BITS_PER_DIM:
            raise ValueError(
                "morton keys above 24 total bits are not exact in float32")


def quantize(coord, lo, hi, bits: int):
    """Map float coords in [lo, hi] to integers in [0, 2^bits - 1]."""
    scale = (1 << bits) / jnp.maximum(hi - lo, 1e-30)
    q = jnp.floor((coord - lo) * scale)
    return jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.uint32)


def spread_bits(v):
    """Spread the low 16 bits of ``v`` to even bit positions (uint32)."""
    v = v.astype(jnp.uint32)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def compact_bits(v):
    """Inverse of :func:`spread_bits` (for tests / decoding)."""
    v = v.astype(jnp.uint32) & jnp.uint32(0x55555555)
    v = (v | (v >> 1)) & jnp.uint32(0x33333333)
    v = (v | (v >> 2)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v >> 4)) & jnp.uint32(0x00FF00FF)
    v = (v | (v >> 8)) & jnp.uint32(0x0000FFFF)
    return v


def morton_encode(qx, qy):
    """Interleave quantized coords: x gets even bits, y odd bits."""
    return spread_bits(qx) | (spread_bits(qy) << jnp.uint32(1))


def morton_decode(key):
    return compact_bits(key), compact_bits(key >> jnp.uint32(1))


def make_keys(x, y, spec: KeySpec):
    """Project float point coords to uint32 sort keys per ``spec``."""
    xlo, ylo, xhi, yhi = spec.bounds
    if spec.kind == "morton":
        qx = quantize(x, xlo, xhi, spec.bits_per_dim)
        qy = quantize(y, ylo, yhi, spec.bits_per_dim)
        return morton_encode(qx, qy)
    if spec.kind == "x":
        return quantize(x, xlo, xhi, spec.bits_per_dim)
    return quantize(y, ylo, yhi, spec.bits_per_dim)


def rect_key_range(rect, spec: KeySpec):
    """[key_lo, key_hi] covering every point inside rect=(xl,yl,xh,yh).

    Valid because morton codes (and axis keys) are monotone in each
    coordinate; see module docstring.
    """
    xl, yl, xh, yh = rect[..., 0], rect[..., 1], rect[..., 2], rect[..., 3]
    xlo, ylo, xhi, yhi = spec.bounds
    if spec.kind == "morton":
        klo = morton_encode(quantize(xl, xlo, xhi, spec.bits_per_dim),
                            quantize(yl, ylo, yhi, spec.bits_per_dim))
        khi = morton_encode(quantize(xh, xlo, xhi, spec.bits_per_dim),
                            quantize(yh, ylo, yhi, spec.bits_per_dim))
    elif spec.kind == "x":
        klo = quantize(xl, xlo, xhi, spec.bits_per_dim)
        khi = quantize(xh, xlo, xhi, spec.bits_per_dim)
    else:
        klo = quantize(yl, ylo, yhi, spec.bits_per_dim)
        khi = quantize(yh, ylo, yhi, spec.bits_per_dim)
    return klo, khi


def keys_to_f32(keys):
    """Exact float32 image of (<=24 bit) integer keys."""
    return keys.astype(jnp.float32)


def z_split_intervals(qxl, qyl, qxh, qyh, valid, *, depth: int = 2):
    """Decompose a quantized rect's morton interval (BIGMIN-style).

    The naive interval [z(lo), z(hi)] includes Z-curve detours outside
    the rect; splitting the rect at the most-significant differing
    morton bit removes the largest detour. ``depth`` recursive splits
    yield up to 2^depth DISJOINT subintervals that still jointly cover
    every in-rect key — the refine phase stays exact while the learned
    scan windows shrink by orders of magnitude (beyond-paper
    optimization; EXPERIMENTS.md §Perf spatial iteration 3).

    Inputs are (...,) uint32 quantized corners + validity. Returns
    (zlo, zhi, piece_valid) with a leading 2^depth axis folded into a
    new trailing dimension: shapes (..., 2^depth).
    """
    def msb_position(v):
        """Highest set bit position of uint32 (0 -> 0); integer-exact."""
        v = v.astype(jnp.uint32)
        v = v | (v >> 1)
        v = v | (v >> 2)
        v = v | (v >> 4)
        v = v | (v >> 8)
        v = v | (v >> 16)
        # popcount via SWAR
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = (v & jnp.uint32(0x33333333)) + ((v >> 2) &
                                            jnp.uint32(0x33333333))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = (v * jnp.uint32(0x01010101)) >> 24
        return jnp.maximum(pc.astype(jnp.int32) - 1, 0)

    pieces = [(qxl, qyl, qxh, qyh, valid)]
    for _ in range(depth):
        nxt = []
        for (xl, yl, xh, yh, v) in pieces:
            zl = morton_encode(xl, yl)
            zh = morton_encode(xh, yh)
            diff = zl ^ zh
            msb = msb_position(diff)
            even = (msb % 2) == 0          # even bits carry x
            b = (msb // 2).astype(jnp.uint32)
            hbx = (xh >> b) << b
            hby = (yh >> b) << b
            nosplit = diff == 0
            x1h = jnp.where(nosplit, xh,
                            jnp.where(even, hbx - 1, xh))
            y1h = jnp.where(nosplit, yh,
                            jnp.where(even, yh, hby - 1))
            x2l = jnp.where(even, hbx, xl)
            y2l = jnp.where(even, yl, hby)
            nxt.append((xl, yl, x1h.astype(jnp.uint32),
                        y1h.astype(jnp.uint32), v))
            nxt.append((x2l.astype(jnp.uint32), y2l.astype(jnp.uint32),
                        xh, yh, v & ~nosplit))
        pieces = nxt
    zlo = jnp.stack([morton_encode(p[0], p[1]) for p in pieces], -1)
    zhi = jnp.stack([morton_encode(p[2], p[3]) for p in pieces], -1)
    pv = jnp.stack([p[4] for p in pieces], -1)
    return zlo, zhi, pv


def data_bounds(x, y, pad_frac: float = 1e-6):
    """Host helper: tight data bounds, padded so max coords quantize inside."""
    x = np.asarray(x)
    y = np.asarray(y)
    xlo, xhi = float(x.min()), float(x.max())
    ylo, yhi = float(y.min()), float(y.max())
    dx = max(xhi - xlo, 1e-12) * pad_frac
    dy = max(yhi - ylo, 1e-12) * pad_frac
    return (xlo, ylo, xhi + dx, yhi + dy)
