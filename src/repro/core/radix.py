"""Float-key radix table (paper §3.2 Algorithm 2).

Compresses the spline knot set: bucket the key range into 2^b equal cells;
``T[j]`` = index of the first knot whose bucket >= j. A lookup for key k
then only binary-searches knots in [T[j], T[j+1]] (j = k's bucket), which
is O(1) on average — the paper's extension of RadixSpline's uint-only
radix table to floating keys.

Built vectorized (searchsorted over knot buckets) rather than the paper's
sequential fill loop — identical table contents, one XLA op.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("bits",))
def build_radix(knot_keys, n_knots, *, bits: int):
    """Build the radix table over spline knots.

    Args:
      knot_keys: (m_pad,) f32 knot keys, padded with +inf-ish.
      n_knots:   () int32.
      bits:      table bits b (paper default 10).

    Returns dict with table (2^b+2,) int32, kmin () f32, scale () f32.
    """
    m_pad = knot_keys.shape[0]
    size = (1 << bits) + 2
    idx = jnp.arange(m_pad)
    valid = idx < n_knots
    kmin = knot_keys[0]
    kmax = knot_keys[jnp.maximum(n_knots - 1, 0)]
    scale = (1 << bits) / jnp.maximum(kmax - kmin, 1e-30)

    bucket = jnp.floor((knot_keys - kmin) * scale).astype(jnp.int32)
    bucket = jnp.clip(bucket, 0, (1 << bits))
    # Padding knots -> past-the-end bucket so they never match.
    bucket = jnp.where(valid, bucket, (1 << bits) + 1)

    # T[j] = first knot index with bucket >= j  (buckets are sorted since
    # knot keys are sorted).
    table = jnp.searchsorted(bucket, jnp.arange(size), side="left")
    table = jnp.clip(table, 0, jnp.maximum(n_knots - 1, 0)).astype(jnp.int32)
    return {"table": table, "kmin": kmin, "scale": scale}


def radix_locate(radix, query_f32, n_knots, *, bits: int):
    """Knot-index search bounds [lo, hi] for each query key."""
    j = jnp.floor((query_f32 - radix["kmin"]) * radix["scale"])
    j = jnp.clip(j, 0, (1 << bits)).astype(jnp.int32)
    lo = radix["table"][j]
    hi = radix["table"][j + 1]
    hi = jnp.clip(hi, lo, jnp.maximum(n_knots - 1, 0))
    return lo, hi


def windowed_segment_search(knot_keys, query_f32, lo, hi):
    """Branchless segment locate restricted to knot window [lo, hi].

    Radix-table contract: the SUCCESSOR knot (first knot with key >= q)
    lies in [T[j], T[j+1]] = [lo, hi]; every knot before ``lo`` has
    key < q. So succ = lo + |{i in [lo,hi] : knot[i] < q}| and the segment
    is succ-1. Implemented as a masked compare-count (VPU-friendly; the
    Pallas kernel uses the same formulation).
    """
    m_pad = knot_keys.shape[0]
    idx = jnp.arange(m_pad)
    q = query_f32[..., None]
    in_win = (idx >= lo[..., None]) & (idx <= hi[..., None])
    lt = (knot_keys < q) & in_win
    succ = lo + jnp.sum(lt.astype(jnp.int32), axis=-1)
    return jnp.maximum(succ - 1, 0)
