"""Pluggable kernel backends for the local SPMD programs (DESIGN.md §10).

Every local program in ``core/local_ops.py`` is structured as three
stages:

  lookup   learned key search — spline/radix lower bounds ([s, e)
           intervals or probe positions) against one partition;
  scan     the per-partition point work inside those bounds (masked
           range counts, kNN distance tiles, ray-casting refine);
  merge    cross-partition / cross-shard reduction (psum, all_gather,
           top-k merge) — owned by the program, never by a backend.

A ``Backend`` supplies the lookup + scan stages. Two implementations:

  xla      the pure-jnp reference (bitwise the seed engine's math; the
           golden parity fixture pins it).
  pallas   routes the scan stage onto the purpose-built TPU kernels in
           ``repro/kernels`` (range_filter, knn_topk, spline_search,
           point_in_polygon). On CPU the kernels run in interpret mode
           (kernels/ops.py auto-detects), so both backends are testable
           everywhere; on TPU they compile to real Mosaic kernels.

Dispatch rules (also DESIGN.md §10):

  - Only the FULL-REFINE scan programs dispatch to kernels: range/circle
    exact counts, the point probe, exact kNN, join refine. They scan
    whole partitions — exactly the tile shape the kernels implement —
    and they are the serving fallback half of every fused (windowed +
    lax.cond) program. Circle counts use the fused circle_filter kernel
    (range filter + distance test in ONE pass); the point probe uses the
    point_probe kernel (window equality scan after the learned lookup).
  - The windowed fast paths gather <= cap candidates via dynamic slices;
    their work is proportional to the learned interval, not to the
    partition, so there is nothing for a scan kernel to win — they stay
    on the XLA gather path under both backends.
  - ``vectorize`` tells the chunk loops how to span partitions: the XLA
    stages vmap cleanly; ``pallas_call`` is dispatched per partition via
    ``lax.map`` (one kernel launch per partition row — the grid already
    parallelizes queries x points inside).

Selection: ``EngineConfig.backend`` is "auto" | "xla" | "pallas";
"auto" picks pallas on TPU and the XLA reference elsewhere. The backend
name is part of every executable-cache key (core/plan.py exec_key), so
one executor never mixes compiled programs across backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import queries as Q

BACKENDS = ("auto", "xla", "pallas")


class XlaBackend:
    """Reference lookup/scan stages in plain jnp (CPU/GPU/TPU)."""

    name = "xla"
    vectorize = True      # stages are safe under vmap over partitions

    # -- lookup stage -----------------------------------------------------

    def lower_bound(self, part, qkf, *, radix_bits: int, probe: int):
        """Exact learned lower_bound positions for (Q,) keys, one part."""
        return Q.learned_lower_bound(part, qkf, radix_bits=radix_bits,
                                     probe=probe)

    def bounds(self, part, klo_f, khi_f, *, radix_bits: int, probe: int):
        """[s, e) covering all keys in [klo, khi] (one kernel-sized
        batch: both ends share one lookup dispatch)."""
        qn = klo_f.shape[0]
        pos = self.lower_bound(part,
                               jnp.concatenate([klo_f, khi_f + 1.0]),
                               radix_bits=radix_bits, probe=probe)
        return pos[:qn], pos[qn:]

    # -- scan stage -------------------------------------------------------

    def filter_mask(self, part, rects, s, e, active=None):
        """(Q, n_pad) bool — in-[s,e) AND in-rect AND valid (the paper's
        filter phase as a mask, for scans that refine further)."""
        n_pad = part["keys_f"].shape[0]
        posn = jnp.arange(n_pad, dtype=jnp.int32)
        valid = posn < part["count"]
        inpos = ((posn[None, :] >= s[:, None]) &
                 (posn[None, :] < e[:, None]))
        xl, yl, xh, yh = (rects[:, 0:1], rects[:, 1:2], rects[:, 2:3],
                          rects[:, 3:4])
        inrect = ((part["x"][None, :] >= xl) &
                  (part["x"][None, :] <= xh) &
                  (part["y"][None, :] >= yl) &
                  (part["y"][None, :] <= yh))
        m = valid[None, :] & inpos & inrect
        if active is not None:
            m = m & active[:, None]
        return m

    def range_scan(self, part, rects, s, e, active=None):
        """(Q,) exact in-rect counts within learned [s, e) intervals."""
        m = self.filter_mask(part, rects, s, e, active)
        return jnp.sum(m.astype(jnp.int32), axis=1)

    def circle_scan(self, part, rects, s, e, circ, active=None):
        """(Q,) exact in-circle counts (MBR filter + distance refine)."""
        m = self.filter_mask(part, rects, s, e, active)
        dx = part["x"][None, :] - circ[:, 0:1]
        dy = part["y"][None, :] - circ[:, 1:2]
        inc = (dx * dx + dy * dy) <= circ[:, 2:3] ** 2
        return jnp.sum((m & inc).astype(jnp.int32), axis=1)

    def point_windows(self, parts, pid, start, probe: int):
        """Gather each query's (probe,) key/x/y window from ITS
        candidate partition (query-centric — ``parts`` is the full
        (P, ...) dict). Shared by both backends: the gather path is
        dynamic slices, nothing for a partition-resident kernel to
        win."""

        def win(arr):
            return jax.vmap(
                lambda p, s: jax.lax.dynamic_slice(arr, (p, s),
                                                   (1, probe))[0]
            )(pid, start)

        return win(parts["keys_f"]), win(parts["x"]), win(parts["y"])

    def point_scan(self, parts, pid, start, qkf, qx, qy, *,
                   probe: int):
        """(Q,) exact membership flags: equality probe of the window
        [start, start+probe) around the learned position in each
        query's candidate partition (paper Alg. 3 collapsed into one
        masked window reduction)."""
        wk, wx, wy = self.point_windows(parts, pid, start, probe)
        return jnp.any((wk == qkf[:, None]) & (wx == qx[:, None]) &
                       (wy == qy[:, None]), axis=1)

    def knn_scan(self, part, qx, qy, k: int):
        """Per-partition kNN candidates: (neg_d2 (Q, W), vid (Q, W)).

        W is backend-defined — the merge stage only concatenates and
        top-ks. The reference returns the full masked distance row
        (W = n_pad), preserving the seed engine's merge order bitwise.
        """
        del k
        n_pad = part["keys_f"].shape[0]
        dx = part["x"][None, :] - qx[:, None]
        dy = part["y"][None, :] - qy[:, None]
        valid = jnp.arange(n_pad)[None, :] < part["count"]
        d2 = jnp.where(valid, dx * dx + dy * dy, 3e38)
        return -d2, jnp.broadcast_to(part["vid"][None, :], d2.shape)

    def join_scan(self, part, polys, n_edges, mbrs, s, e, active=None):
        """(PG,) per-polygon contained-point counts (filter + ray cast)."""
        m = self.filter_mask(part, mbrs, s, e, active)

        def pip(poly, ne, mask):
            inside = Q.point_in_polygon(part["x"], part["y"], poly, ne)
            return jnp.sum((mask & inside).astype(jnp.int32))

        return jax.vmap(pip)(polys, n_edges, m)

    # -- delta stage ------------------------------------------------------
    # Live delta-buffer probes (DESIGN.md §11). Buffers hold <= d_cap
    # points per partition, so a full masked scan IS the optimal plan —
    # like the windowed gathers, there is nothing for a partition-
    # resident kernel to win, and both backends share this jnp path
    # (PallasBackend inherits).

    def delta_live(self, part):
        """(d_cap,) live-slot mask of one partition's delta buffer
        (the per-row form of queries.gather_delta's liveness rule —
        change both together)."""
        slot = jnp.arange(part["dvid"].shape[0], dtype=jnp.int32)
        return (slot < part["dcount"]) & (part["dvid"] >= 0)

    def delta_scan(self, part, rects, circ=None, active=None):
        """(Q,) live buffered points in each rect (and circle)."""
        live = self.delta_live(part)
        xl, yl, xh, yh = (rects[:, 0:1], rects[:, 1:2], rects[:, 2:3],
                          rects[:, 3:4])
        m = (live[None, :] &
             (part["dx"][None, :] >= xl) & (part["dx"][None, :] <= xh) &
             (part["dy"][None, :] >= yl) & (part["dy"][None, :] <= yh))
        if circ is not None:
            dx = part["dx"][None, :] - circ[:, 0:1]
            dy = part["dy"][None, :] - circ[:, 1:2]
            m = m & (dx * dx + dy * dy <= circ[:, 2:3] ** 2)
        if active is not None:
            m = m & active[:, None]
        return jnp.sum(m.astype(jnp.int32), axis=1)

    def delta_join_scan(self, part, polys, n_edges, mbrs, active=None):
        """(PG,) buffered points contained in each polygon."""
        live = self.delta_live(part)
        xl, yl, xh, yh = (mbrs[:, 0:1], mbrs[:, 1:2], mbrs[:, 2:3],
                          mbrs[:, 3:4])
        m = (live[None, :] &
             (part["dx"][None, :] >= xl) & (part["dx"][None, :] <= xh) &
             (part["dy"][None, :] >= yl) & (part["dy"][None, :] <= yh))
        if active is not None:
            m = m & active[:, None]

        def pip(poly, ne, mask):
            inside = Q.point_in_polygon(part["dx"], part["dy"], poly, ne)
            return jnp.sum((mask & inside).astype(jnp.int32))

        return jax.vmap(pip)(polys, n_edges, m)

    def delta_knn_scan(self, part, qx, qy):
        """Buffered kNN candidates: (neg_d2 (Q, d_cap), vid (Q, d_cap))
        — merged by the program exactly like main-plane candidates."""
        live = self.delta_live(part)
        dx = part["dx"][None, :] - qx[:, None]
        dy = part["dy"][None, :] - qy[:, None]
        d2 = jnp.where(live[None, :], dx * dx + dy * dy, 3e38)
        vid = jnp.where(live, part["dvid"], -1)
        return -d2, jnp.broadcast_to(vid[None, :], d2.shape)


class PallasBackend(XlaBackend):
    """Scan stages on the Pallas TPU kernels (interpret mode off-TPU).

    Every full-refine scan stage has a dedicated kernel now (range,
    fused circle, point probe, kNN, join refine); only ``filter_mask``
    remains reference-shared. ``interpret=None`` defers to
    kernels/ops.py (interpret unless running on a real TPU).
    """

    name = "pallas"
    vectorize = False     # one pallas_call per partition row (lax.map)

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = interpret

    def lower_bound(self, part, qkf, *, radix_bits: int, probe: int):
        from repro.kernels import ops
        return ops.spline_search(
            qkf, part["knot_keys"], part["knot_pos"],
            part["radix_table"], part["keys_f"], part["radix_kmin"],
            part["radix_scale"], part["n_knots"], part["count"],
            probe=probe, radix_bits=radix_bits, interpret=self.interpret)

    def circle_scan(self, part, rects, s, e, circ, active=None):
        from repro.kernels import ops
        se = jnp.stack([s, e], axis=1).astype(jnp.float32)
        cnt = ops.circle_count(rects, se, circ, part["count"],
                               part["x"], part["y"],
                               interpret=self.interpret)
        if active is not None:
            cnt = jnp.where(active, cnt, 0)
        return cnt

    def point_scan(self, parts, pid, start, qkf, qx, qy, *,
                   probe: int):
        from repro.kernels import ops
        wk, wx, wy = self.point_windows(parts, pid, start, probe)
        hits = ops.point_probe(qkf, qx, qy, wk, wx, wy, probe=probe,
                               interpret=self.interpret)
        return hits > 0

    def range_scan(self, part, rects, s, e, active=None):
        from repro.kernels import ops
        se = jnp.stack([s, e], axis=1).astype(jnp.float32)
        cnt = ops.range_count(rects, se, part["count"], part["x"],
                              part["y"], interpret=self.interpret)
        if active is not None:
            # inactive queries cannot count points here (their rect does
            # not overlap this partition's box) — masking matches the
            # reference's in-mask AND exactly
            cnt = jnp.where(active, cnt, 0)
        return cnt

    def knn_scan(self, part, qx, qy, k: int):
        from repro.kernels import knn_topk as _knn
        from repro.kernels import ops
        qxy = jnp.stack([qx, qy], axis=1)
        negd, idx = ops.knn_topk(qxy, part["count"], part["x"],
                                 part["y"], k=k, interpret=self.interpret)
        # kernel idx are partition positions; map through vid, keeping
        # the reference's -1 for sub-k partitions (NEG-valued slots)
        vid = part["vid"][jnp.clip(idx, 0, part["vid"].shape[0] - 1)]
        vid = jnp.where((idx >= 0) & (negd > _knn.NEG), vid, -1)
        return negd, vid

    def join_scan(self, part, polys, n_edges, mbrs, s, e, active=None):
        from repro.kernels import ops
        m = self.filter_mask(part, mbrs, s, e, active)

        def pip(args):
            poly, ne, mask = args
            inside = ops.point_in_polygon(poly, ne, part["x"],
                                          part["y"],
                                          interpret=self.interpret)
            return jnp.sum((mask & (inside > 0)).astype(jnp.int32))

        return jax.lax.map(pip, (polys, n_edges, m))


def resolve_backend(name: str = "auto",
                    interpret: Optional[bool] = None):
    """Backend instance from an EngineConfig.backend string.

    "auto" picks the Pallas kernels when running on real TPU hardware
    and the XLA reference elsewhere; "pallas" forces the kernels (they
    run in interpret mode off-TPU, so this is valid — just slow — on
    CPU, which is exactly what the parity suite exercises).
    """
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}: expected one of {BACKENDS}")
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "xla"
    if name == "pallas":
        return PallasBackend(interpret=interpret)
    return XlaBackend()
