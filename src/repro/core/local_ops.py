"""Per-shard local query programs (paper §3-4, DESIGN.md §2/§9/§10).

Each class below is a local SPMD program: a callable
``fn(parts, bounds, *query_args, axis=...)`` with attribute
``n_query_args`` so the executor knows its signature. ``bounds`` is the
REPLICATED global index; ``parts`` leaves are LOCAL partition shards.
The executor (core/executor.py) owns jit + shard_map wrapping, the
executable cache, and the adaptive-cap policy; nothing here retries or
synchronizes with the host.

Every program is staged lookup -> scan -> merge (DESIGN.md §10): the
lookup (learned bounds) and scan (per-partition point work) stages come
from the pluggable kernel backend (core/backends.py — XLA reference or
the Pallas TPU kernels); the merge stage (collectives) stays here:

  point  -> psum (boolean OR as integer sum)
  range  -> psum of counts / all_gather of windowed candidate ids
  kNN    -> per-shard top-k, all_gather, merge top-k
  join   -> psum of per-polygon counts
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core import queries as Q
from repro.core.build import LearnedSpatialIndex
from repro.core.plan import EngineConfig

EMPTY_BOX = np.asarray([3e38, 3e38, -3e38, -3e38], np.float32)


def pad_partitions(index: LearnedSpatialIndex, multiple: int
                   ) -> LearnedSpatialIndex:
    """Pad the partition axis with empty partitions (never match queries)."""
    p = index.num_partitions
    p_pad = int(np.ceil(p / multiple) * multiple)
    if p_pad == p:
        return index
    extra = p_pad - p

    def pad(a, fill):
        pad_block = jnp.full((extra,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, pad_block], axis=0)

    def pad_opt(a, fill):
        return None if a is None else pad(a, fill)

    return dataclasses.replace(
        index,
        key=pad(index.key, index.key_spec.sentinel),
        x=pad(index.x, 3e38), y=pad(index.y, 3e38), vid=pad(index.vid, -1),
        count=pad(index.count, 0),
        knot_keys=pad(index.knot_keys, 3e38),
        knot_pos=pad(index.knot_pos, 0.0),
        n_knots=pad(index.n_knots, 0),
        radix_table=pad(index.radix_table, 0),
        radix_kmin=pad(index.radix_kmin, 0.0),
        radix_scale=pad(index.radix_scale, 0.0),
        part_bounds=jnp.concatenate(
            [index.part_bounds,
             jnp.broadcast_to(jnp.asarray(EMPTY_BOX), (extra, 4))], axis=0),
        delta_key=pad_opt(index.delta_key, index.key_spec.sentinel),
        delta_x=pad_opt(index.delta_x, 3e38),
        delta_y=pad_opt(index.delta_y, 3e38),
        delta_vid=pad_opt(index.delta_vid, -1),
        delta_count=pad_opt(index.delta_count, 0),
        dead=pad_opt(index.dead, 0),
        max_run=pad_opt(index.max_run, 0),
        refit_gen=pad_opt(index.refit_gen, 0),
        # the true overflow grid keeps its pre-padding position
        overflow_pid=index.overflow,
    )


def part_leaf_names(index: LearnedSpatialIndex) -> set:
    """Leaf names part_arrays would produce (no arrays materialized)."""
    names = {"keys_f", "x", "y", "vid", "count", "knot_keys",
             "knot_pos", "n_knots", "radix_table", "radix_kmin",
             "radix_scale"}
    if index.delta_cap:
        names |= {"dx", "dy", "dvid", "dcount"}
    return names


def part_arrays(index: LearnedSpatialIndex, leaves=None) -> dict:
    """Shardable dict-of-arrays view (leading axis = partitions).

    The delta-buffer leaves appear only when the index carries a
    non-zero delta capacity, so frozen-index programs (and the dry-run
    harness, which builds this dict by hand) are unchanged. ``leaves``
    restricts the result to the named subset — the executor's update
    path refreshes only the planes a mutation touched, and in
    particular skips the O(N) keys_f cast unless the key plane moved.
    """
    parts = {
        "x": index.x, "y": index.y, "vid": index.vid,
        "count": index.count,
        "knot_keys": index.knot_keys, "knot_pos": index.knot_pos,
        "n_knots": index.n_knots, "radix_table": index.radix_table,
        "radix_kmin": index.radix_kmin, "radix_scale": index.radix_scale,
    }
    if index.delta_cap:
        parts.update({
            "dx": index.delta_x, "dy": index.delta_y,
            "dvid": index.delta_vid, "dcount": index.delta_count,
        })
    if leaves is None or "keys_f" in leaves:
        parts["keys_f"] = K.keys_to_f32(index.key)
    if leaves is not None:
        return {k: parts[k] for k in leaves}
    return parts


def _map_parts(f, parts, chunk: int, init=None):
    """Sequential lax.map over partition chunks (bounds peak memory).

    f(chunk_parts, carry) -> carry ; chunk_parts leaves (C, ...).
    """
    p = parts["count"].shape[0]
    c = min(chunk, p)
    assert p % c == 0, (p, c)
    chunked = jax.tree_util.tree_map(
        lambda a: a.reshape((p // c, c) + a.shape[1:]), parts)

    def step(carry, ch):
        return f(ch, carry), None

    carry, _ = jax.lax.scan(step, init, chunked)
    return carry


def _for_parts(backend, f, xs):
    """Span f over one chunk's partitions, backend-appropriately.

    The XLA stages vectorize (vmap); a pallas_call is dispatched once
    per partition row via lax.map — its grid already parallelizes
    queries x points, and batching rules for kernels are not relied on.
    ``xs`` is a tuple of per-partition-stacked args; returns stacked
    outputs either way.
    """
    if backend.vectorize:
        return jax.vmap(f)(*xs)
    return jax.lax.map(lambda a: f(*a), xs)


def _edge_mask(polys, n_edges):
    e = polys.shape[1]
    return (jnp.arange(e)[None, :, None] < n_edges[:, None, None])


def _axes(axis):
    return axis if isinstance(axis, tuple) else (axis,)


def _psum(x, axis):
    return x if axis is None else jax.lax.psum(x, axis)


def _top_candidates(flags, c: int):
    """First C true columns per row of (Q, P) flags.

    lax.top_k on a descending column-priority score — O(P*C) instead of
    the O(P log P) full argsort it replaces; top_k's lowest-index
    tie-break reproduces the stable sort's layout bitwise (true columns
    ascending, then false columns ascending).

    Returns (pids (Q, C) int32, valid (Q, C), within (Q,) — True when the
    row had <= C candidates, i.e. the result is complete)."""
    qn, p = flags.shape
    c = min(c, p)
    col = jnp.arange(p, dtype=jnp.int32)
    score = jnp.where(flags, p - col, 0)
    _, order = jax.lax.top_k(score, c)
    valid = jnp.take_along_axis(flags, order, axis=1)
    within = jnp.sum(flags.astype(jnp.int32), axis=1) <= c
    return order.astype(jnp.int32), valid, within


def _keep_window(vids, cnt, cap: int):
    """Compact materialized ids to the front, bounded keep width.

    Cumsum stream compaction: the running count of valid ids gives each
    output slot k its source position (the first index whose cumsum
    reaches k+1, found by searchsorted on the monotone cumsum row), so
    the kept window is ONE gather — O(W + keep log W) instead of the
    O(W log W) full-width argsort this replaces, with the identical
    (order-preserving) layout. The gather formulation is deliberate:
    the equivalent scatter (slot per valid id) is scalarized by XLA:CPU
    and measures ~12x slower at serving widths.

    Returns (vids (Q, keep), cap_ok (Q,) — True when no id was dropped).
    """
    qn, w = vids.shape
    keep = min(w, max(cap * 8, 256))
    cum = jnp.cumsum((vids >= 0).astype(jnp.int32), axis=1)
    tgt = jnp.arange(1, keep + 1, dtype=jnp.int32)
    idx = jax.vmap(lambda c: jnp.searchsorted(c, tgt))(cum)
    kept = jnp.take_along_axis(vids, jnp.minimum(idx, w - 1), axis=1)
    kept = jnp.where(tgt[None, :] <= cum[:, -1:], kept, -1)
    cap_ok = jnp.sum((kept >= 0).astype(jnp.int32), axis=1) == cnt
    return kept, cap_ok


def _delta_knn_candidates(parts, pid, valid, qx, qy, r):
    """Live buffered candidates within radius r of (Q, C) candidate
    partitions (the kNN delta probe, DESIGN.md §11; liveness comes
    from the shared Q.gather_delta rule).

    Returns (counts (Q,), vids (Q, C*d_cap), neg_d2 (Q, C*d_cap)).
    """
    qn = pid.shape[0]
    dx, dy, dv, live = Q.gather_delta(parts, pid, valid)
    d2 = ((dx - qx[:, None, None]) ** 2 + (dy - qy[:, None, None]) ** 2)
    inc = live & (d2 <= (r * r)[:, None, None])
    return (jnp.sum(inc.astype(jnp.int32), axis=(1, 2)),
            jnp.where(inc, dv, -1).reshape(qn, -1),
            jnp.where(inc, -d2, -3e38).reshape(qn, -1))


# ---------------------------------------------------------------------------
# local programs
# ---------------------------------------------------------------------------

class _LocalFn:
    def __init__(self, index: LearnedSpatialIndex, cfg: EngineConfig,
                 backend):
        self.kw = dict(radix_bits=index.radix_bits, probe=index.probe)
        self.cfg = cfg
        self.backend = backend
        self.p_total = index.num_partitions
        self.n_pad = index.n_pad
        self.spec = index.key_spec
        # static: d_cap == 0 compiles the delta probes away entirely,
        # keeping frozen-index programs bitwise the pre-update ones
        self.d_cap = index.delta_cap
        self.overflow = index.overflow

    def _local_offset(self, axis, p_loc):
        if axis is None:
            return jnp.int32(0)
        idx = jnp.int32(0)
        mul = jnp.int32(1)
        for a in reversed(axis):
            idx = idx + jax.lax.axis_index(a) * mul
            # psum(1) == axis size; works on jax versions without
            # jax.lax.axis_size
            mul = mul * jax.lax.psum(1, a)
        return idx * p_loc


class _PointLocal(_LocalFn):
    """Staged point probe, query-centric: each query touches only its
    first-match grid partition and the overflow grid (paper Alg. 1) —
    never a partition sweep. The lookup is the shared query-centric
    learned search (Q.lower_bound_at, one knot-row gather per query);
    the scan is the backend's point_scan stage over the gathered probe
    windows (the pallas backend reduces the whole batch in ONE
    point_probe kernel launch)."""

    n_query_args = 3

    def __call__(self, parts, bounds, qx, qy, qk, *, axis):
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        bk = self.backend
        probe = self.kw["probe"]
        n_pad = parts["keys_f"].shape[1]
        # global filter: first-match grid (paper Alg. 1 semantics) and the
        # overflow grid are the only partitions that can contain the point.
        inb = Q.point_in_box(qx, qy, bounds[:self.overflow])  # (Q, G)
        hit = jnp.any(inb, axis=1)
        pid1 = jnp.where(hit, jnp.argmax(inb, axis=1).astype(jnp.int32),
                         self.overflow)
        pid2 = jnp.full_like(pid1, self.overflow)         # overflow grid

        def probe_pid(pid):
            lid = pid - off
            mine = (lid >= 0) & (lid < p_loc)
            lid = jnp.clip(lid, 0, p_loc - 1)
            pos = Q.lower_bound_at(parts, lid, qk, **self.kw)  # lookup
            start = jnp.clip(pos - probe // 2, 0, n_pad - probe)
            f = bk.point_scan(parts, lid, start, qk, qx, qy,   # scan
                              probe=probe)
            if self.d_cap:                                 # delta probe
                ddx, ddy, _, live = Q.gather_delta(
                    parts, lid[:, None], mine[:, None])
                f = f | jnp.any(live[:, 0] &
                                (ddx[:, 0] == qx[:, None]) &
                                (ddy[:, 0] == qy[:, None]), axis=1)
            return f & mine

        found = probe_pid(pid1) | probe_pid(pid2)
        return _psum(found.astype(jnp.int32), axis)           # merge


class _RangeCountLocal(_LocalFn):
    n_query_args = 3

    def __call__(self, parts, bounds, rects, klo, khi, *, axis):
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        bk = self.backend
        overlap = Q.rect_overlaps_box(rects, bounds)      # (Q, P_total)

        def chunk_fn(ch, carry):
            c = ch["count"].shape[0]
            base = carry["i"] * c + off

            def one(j, part):
                act = jax.lax.dynamic_index_in_dim(
                    overlap, base + j, axis=1, keepdims=False)
                s, e = bk.bounds(part, klo, khi, **self.kw)   # lookup
                cnt = bk.range_scan(part, rects, s, e,        # scan
                                    active=act)
                if self.d_cap:
                    cnt = cnt + bk.delta_scan(part, rects, active=act)
                return cnt

            cnts = _for_parts(bk, one, (jnp.arange(c), ch))   # (C, Q)
            return {"i": carry["i"] + 1,
                    "acc": carry["acc"] + jnp.sum(cnts, axis=0)}

        out = _map_parts(chunk_fn, parts, self.cfg.part_chunk,
                         init={"i": jnp.int32(0),
                               "acc": jnp.zeros(rects.shape[0], jnp.int32)})
        return _psum(out["acc"], axis)                        # merge


class _CircleCountLocal(_LocalFn):
    """Exact full-refine circle count (fallback / gridonly baseline)."""

    n_query_args = 4

    def __call__(self, parts, bounds, rects, klo, khi, circ, *, axis):
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        bk = self.backend
        overlap = Q.rect_overlaps_box(rects, bounds)

        def chunk_fn(ch, carry):
            c = ch["count"].shape[0]
            base = carry["i"] * c + off

            def one(j, part):
                act = jax.lax.dynamic_index_in_dim(
                    overlap, base + j, axis=1, keepdims=False)
                s, e = bk.bounds(part, klo, khi, **self.kw)   # lookup
                cnt = bk.circle_scan(part, rects, s, e, circ,  # scan
                                     active=act)
                if self.d_cap:
                    cnt = cnt + bk.delta_scan(part, rects, circ=circ,
                                              active=act)
                return cnt

            cnts = _for_parts(bk, one, (jnp.arange(c), ch))
            return {"i": carry["i"] + 1,
                    "acc": carry["acc"] + jnp.sum(cnts, axis=0)}

        out = _map_parts(chunk_fn, parts, self.cfg.part_chunk,
                         init={"i": jnp.int32(0),
                               "acc": jnp.zeros(rects.shape[0], jnp.int32)})
        return _psum(out["acc"], axis)                        # merge


class _RangeWindowLocal(_LocalFn):
    """Query-centric windowed range query (the paper's two-phase shape):
    phase 1 selects the <=C candidate partitions per query from the
    replicated global index; phase 2 gathers ONLY each candidate's
    learned key interval (cap slots). Work ~ Q x C x cap, independent of
    the total partition count and of partition size."""

    n_query_args = 3

    def __init__(self, index, cfg, backend, cap, cand):
        super().__init__(index, cfg, backend)
        self.cap = min(cap, index.n_pad)
        self.cand = cand

    def __call__(self, parts, bounds, rects, klo, khi, *, axis):
        del klo, khi   # recomputed per-candidate with clipping
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        qn = rects.shape[0]
        overlap = Q.rect_overlaps_box(rects, bounds)       # (Q, P_total)
        pids, valid, within = _top_candidates(overlap, self.cand)
        boxes = bounds[pids.reshape(-1)].reshape(qn, self.cand, 4)
        local = pids - off
        mine = valid & (local >= 0) & (local < p_loc)
        local = jnp.clip(local, 0, p_loc - 1)
        cnts, vids, ok, _, _ = Q.range_window_at(
            parts, boxes, local, mine, rects, self.spec, cap=self.cap,
            **self.kw)
        if self.d_cap:
            dcnts, dvids = Q.delta_window_at(parts, local, mine, rects)
            cnts = cnts + dcnts
            vids = jnp.concatenate([vids, dvids], axis=-1)
        cnt = _psum(jnp.sum(cnts, axis=1), axis)
        vids = vids.reshape(qn, -1)
        okq = jnp.all(ok | ~mine, axis=1)
        if axis is not None:
            vids = jax.lax.all_gather(vids, axis, axis=1, tiled=True)
            shards = jax.lax.psum(1, axis)
            okq = jax.lax.psum(okq.astype(jnp.int32), axis) == shards
        vids, cap_ok = _keep_window(vids, cnt, self.cap)
        return cnt, vids, okq & within & cap_ok


class _CircleWindowLocal(_LocalFn):
    """Adaptive windowed circle query: the distance refine (paper
    Remark 2) is FUSED into the per-subinterval window gather
    (Q.circle_window_at), so this program receives pre-refined in-circle
    counts plus compacted ids and never materializes the (Q, C, S*cap)
    wx/wy coordinate planes. Exact when ok; the executor escalates /
    falls back to the full-refine _CircleCountLocal otherwise."""

    n_query_args = 4

    def __init__(self, index, cfg, backend, cap, cand,
                 materialize: bool):
        super().__init__(index, cfg, backend)
        self.cap = min(cap, index.n_pad)
        self.cand = cand
        self.materialize = materialize

    def __call__(self, parts, bounds, rects, klo, khi, circ, *, axis):
        del klo, khi   # recomputed per-candidate with clipping
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        qn = rects.shape[0]
        overlap = Q.rect_overlaps_box(rects, bounds)
        pids, valid, within = _top_candidates(overlap, self.cand)
        boxes = bounds[pids.reshape(-1)].reshape(qn, self.cand, 4)
        local = pids - off
        mine = valid & (local >= 0) & (local < p_loc)
        local = jnp.clip(local, 0, p_loc - 1)
        cnts, vids, ok = Q.circle_window_at(
            parts, boxes, local, mine, rects, circ, self.spec,
            cap=self.cap, materialize=self.materialize, **self.kw)
        if self.d_cap:
            dcnts, dvids = Q.delta_window_at(parts, local, mine, rects,
                                             circ=circ)
            cnts = cnts + dcnts
            if self.materialize:
                vids = jnp.concatenate([vids, dvids], axis=-1)
        cnt = _psum(jnp.sum(cnts, axis=1), axis)
        okq = jnp.all(ok | ~mine, axis=1)
        if axis is not None:
            shards = jax.lax.psum(1, axis)
            okq = jax.lax.psum(okq.astype(jnp.int32), axis) == shards
        if not self.materialize:
            return cnt, okq & within
        vids = vids.reshape(qn, -1)
        if axis is not None:
            vids = jax.lax.all_gather(vids, axis, axis=1, tiled=True)
        vids, cap_ok = _keep_window(vids, cnt, self.cap)
        return cnt, vids, okq & within & cap_ok


class _KnnExactLocal(_LocalFn):
    n_query_args = 2

    def __init__(self, index, cfg, backend, k):
        super().__init__(index, cfg, backend)
        self.k = k

    def __call__(self, parts, bounds, qx, qy, *, axis):
        qn = qx.shape[0]
        k = self.k
        bk = self.backend

        def chunk_fn(ch, carry):
            def one(part):
                # scan stage: (Q, W) per-partition candidates — W is the
                # full row for xla, the kernel's top-k for pallas; the
                # delta probe appends its (tiny) buffered candidates
                neg, vid = bk.knn_scan(part, qx, qy, k)
                if self.d_cap:
                    dneg, dvid = bk.delta_knn_scan(part, qx, qy)
                    neg = jnp.concatenate([neg, dneg], axis=1)
                    vid = jnp.concatenate([vid, dvid], axis=1)
                return neg, vid

            neg, vid = _for_parts(bk, one, (ch,))          # (C, Q, W)
            neg = jnp.swapaxes(neg, 0, 1).reshape(qn, -1)
            vid = jnp.swapaxes(vid, 0, 1).reshape(qn, -1)
            cand_n = jnp.concatenate([carry[0], neg], axis=1)
            cand_v = jnp.concatenate([carry[1], vid], axis=1)
            best_n, ix = jax.lax.top_k(cand_n, k)          # merge
            best_v = jnp.take_along_axis(cand_v, ix, axis=1)
            return best_n, best_v

        init = (jnp.full((qn, k), -3e38, jnp.float32),
                jnp.full((qn, k), -1, jnp.int32))
        neg, vid = _map_parts(chunk_fn, parts, self.cfg.part_chunk, init)
        if axis is not None:
            neg = jax.lax.all_gather(neg, axis, axis=1, tiled=True)
            vid = jax.lax.all_gather(vid, axis, axis=1, tiled=True)
            best_n, ix = jax.lax.top_k(neg, k)
            vid = jnp.take_along_axis(vid, ix, axis=1)
            neg = best_n
        return neg, vid


class _KnnPrunedLocal(_LocalFn):
    """Paper §4.3, query-centric: density-estimated radius, windowed
    range gather over the <=C nearest candidate partitions, geometric
    expansion until >=k verified in-circle candidates. Exact when ok;
    the executor falls back to the full scan per unresolved query."""

    n_query_args = 3

    def __init__(self, index, cfg, backend, k, spec, cand, cap):
        super().__init__(index, cfg, backend)
        self.k = k
        self.spec2 = spec
        self.cand = cand
        self.cap = min(cap, index.n_pad)

    def __call__(self, parts, bounds, qx, qy, r0, *, axis):
        qn = qx.shape[0]
        k = self.k
        cap = self.cap
        cand = self.cand
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        boxd2 = Q.box_min_dist2(qx, qy, bounds)            # (Q, P_total)
        # C nearest partitions by box distance (static per query batch):
        # lax.top_k on negated distances — O(P*C) vs the full argsort,
        # identical order (top_k's lowest-index tie-break matches the
        # stable ascending sort)
        negd2, order = jax.lax.top_k(-boxd2, cand)
        cand_d2 = -negd2
        boxes = bounds[order.reshape(-1)].reshape(qn, cand, 4)
        local = order - off
        inshard = (local >= 0) & (local < p_loc)
        local = jnp.clip(local, 0, p_loc - 1)

        def gather_round(r):
            rects = jnp.stack([qx - r, qy - r, qx + r, qy + r], axis=-1)
            active = inshard & (cand_d2 <= (r * r)[:, None])
            # coverage: every partition within r must be a candidate
            covered = jnp.sum((boxd2 <= (r * r)[:, None]).astype(
                jnp.int32), axis=1) <= cand
            cnts, vids, ok, wx, wy = Q.range_window_at(
                parts, boxes, local, active, rects, self.spec2,
                cap=cap, **self.kw)
            d2 = ((wx - qx[:, None, None]) ** 2 +
                  (wy - qy[:, None, None]) ** 2)
            inc = (vids >= 0) & (d2 <= (r * r)[:, None, None])
            negd = jnp.where(inc, -d2, -3e38).reshape(qn, -1)
            wv = jnp.where(inc, vids, -1).reshape(qn, -1)
            cnt = jnp.sum(inc.astype(jnp.int32), axis=(1, 2))
            if self.d_cap:
                # buffered candidates of the same candidate partitions:
                # an insert is in-circle iff within r (coverage already
                # guarantees every in-range partition is a candidate)
                dcnts, dvids, dd2 = _delta_knn_candidates(
                    parts, local, active, qx, qy, r)
                negd = jnp.concatenate([negd, dd2], axis=1)
                wv = jnp.concatenate([wv, dvids], axis=1)
                cnt = cnt + dcnts
            bn, ix = jax.lax.top_k(negd, k)
            bv = jnp.take_along_axis(wv, ix, axis=1)
            okq = jnp.all(ok | ~active, axis=1) & covered
            if axis is not None:
                bn_g = jax.lax.all_gather(bn, axis, axis=1, tiled=True)
                bv_g = jax.lax.all_gather(bv, axis, axis=1, tiled=True)
                bn, ix = jax.lax.top_k(bn_g, k)
                bv = jnp.take_along_axis(bv_g, ix, axis=1)
                cnt = jax.lax.psum(cnt, axis)
                okq = jax.lax.psum(okq.astype(jnp.int32), axis) == \
                    jax.lax.psum(1, axis)
            return bn, bv, okq, cnt

        def cond(state):
            rounds, r, done, *_ = state
            return (rounds < self.cfg.knn_max_rounds) & ~jnp.all(done)

        def body(state):
            rounds, r, done, bn, bv, okc = state
            bn2, bv2, ok2, cnt2 = gather_round(r)
            newly = (cnt2 >= k) & ok2 & ~done
            bn = jnp.where(newly[:, None], bn2, bn)
            bv = jnp.where(newly[:, None], bv2, bv)
            okc = okc | newly
            done2 = done | newly | ~ok2        # overflow -> fallback
            r2 = jnp.where(done2, r, r * 2.0)
            return rounds + 1, r2, done2, bn, bv, okc

        state = (jnp.int32(0), r0, jnp.zeros(qn, bool),
                 jnp.full((qn, k), -3e38, jnp.float32),
                 jnp.full((qn, k), -1, jnp.int32), jnp.zeros(qn, bool))
        _, _, done, bn, bv, okc = jax.lax.while_loop(cond, body, state)
        return bn, bv, okc & done


class _JoinLocal(_LocalFn):
    """Query-centric windowed broadcast join: per polygon, gather only
    the learned MBR interval of its <=C candidate partitions, refine by
    ray casting on those <= C*cap points."""

    n_query_args = 3

    def __init__(self, index, cfg, backend, cap, cand):
        super().__init__(index, cfg, backend)
        self.cap = min(cap, index.n_pad)
        self.cand = cand

    def __call__(self, parts, bounds, polys, n_edges, mbr_k, *, axis):
        pg = polys.shape[0]
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        mbrs = mbr_k[:, :4]
        overlap = Q.rect_overlaps_box(mbrs, bounds)
        pids, valid, within = _top_candidates(overlap, self.cand)
        boxes = bounds[pids.reshape(-1)].reshape(pg, self.cand, 4)
        local = pids - off
        mine = valid & (local >= 0) & (local < p_loc)
        local = jnp.clip(local, 0, p_loc - 1)
        cnts, vids, ok, wx, wy = Q.range_window_at(
            parts, boxes, local, mine, mbrs, self.spec, cap=self.cap,
            z_depth=3, **self.kw)
        if self.d_cap:
            dxw, dyw, dvw, live = Q.gather_delta(parts, local, mine)
            r = mbrs[:, None, None, :]
            inm = (live & (dxw >= r[..., 0]) & (dxw <= r[..., 2]) &
                   (dyw >= r[..., 1]) & (dyw <= r[..., 3]))
            wx = jnp.concatenate([wx, dxw], axis=-1)
            wy = jnp.concatenate([wy, dyw], axis=-1)
            vids = jnp.concatenate([vids, jnp.where(inm, dvw, -1)],
                                   axis=-1)

        def pip(poly, ne, wxq, wyq, vq):
            inside = Q.point_in_polygon(wxq.reshape(-1),
                                        wyq.reshape(-1), poly, ne)
            return jnp.sum(((vq.reshape(-1) >= 0) & inside
                            ).astype(jnp.int32))

        cnt = jax.vmap(pip)(polys, n_edges, wx, wy, vids)
        cnt = _psum(cnt, axis)
        okq = jnp.all(ok | ~mine, axis=1)
        if axis is not None:
            shards = jax.lax.psum(1, axis)
            okq = jax.lax.psum(okq.astype(jnp.int32), axis) == shards
        return cnt, okq & within


class _JoinFullLocal(_LocalFn):
    """Exact full-refine join (fallback / gridonly baseline)."""

    n_query_args = 3

    def __call__(self, parts, bounds, polys, n_edges, mbr_k, *, axis):
        pg = polys.shape[0]
        p_loc = parts["count"].shape[0]
        off = self._local_offset(axis, p_loc)
        bk = self.backend
        mbrs, klo, khi = mbr_k[:, :4], mbr_k[:, 4], mbr_k[:, 5]
        overlap = Q.rect_overlaps_box(mbrs, bounds)

        def chunk_fn(ch, carry):
            c = ch["count"].shape[0]
            base = carry["i"] * c + off

            def one(j, part):
                act = jax.lax.dynamic_index_in_dim(
                    overlap, base + j, axis=1, keepdims=False)
                s, e = bk.bounds(part, klo, khi, **self.kw)   # lookup
                cnt = bk.join_scan(part, polys, n_edges, mbrs,  # scan
                                   s, e, active=act)
                if self.d_cap:
                    cnt = cnt + bk.delta_join_scan(part, polys, n_edges,
                                                   mbrs, active=act)
                return cnt

            cnts = _for_parts(bk, one, (jnp.arange(c), ch))   # (C, PG)
            return {"i": carry["i"] + 1,
                    "acc": carry["acc"] + jnp.sum(cnts, axis=0)}

        out = _map_parts(chunk_fn, parts, self.cfg.part_chunk,
                         init={"i": jnp.int32(0),
                               "acc": jnp.zeros(pg, jnp.int32)})
        return _psum(out["acc"], axis)                        # merge


class _CondFusedLocal(_LocalFn):
    """Windowed primary + lax.cond exact fallback, fused in ONE program.

    The steady-state zero-host-sync path (DESIGN.md §9): the primary
    windowed attempt runs at the sticky (cap, cand); when any query
    overflowed, lax.cond dispatches the exact fallback ON DEVICE — the
    host never inspects ``ok``. The cond predicate is replicated (ok is
    psum-merged in the primary), so all shards take the same branch.

    primary(parts, bounds, *q)              -> pytree containing ok
    fallback(parts, bounds, *q[fb_args])    -> exact pytree
    merge_ok(pri) / merge_fb(pri, fb)       -> SAME output structure

    Returns (merged_result, ok): the replicated per-query ok flags ride
    along so the executor can stash them for a DEFERRED host check
    (Executor.maintain) without syncing on the dispatch path.
    """

    def __init__(self, index, cfg, backend, primary, fallback, fb_args,
                 get_ok, merge_ok, merge_fb):
        super().__init__(index, cfg, backend)
        self.primary = primary
        self.fallback = fallback
        self.fb_args = fb_args
        self.get_ok = get_ok
        self.merge_ok = merge_ok
        self.merge_fb = merge_fb
        self.n_query_args = primary.n_query_args

    def __call__(self, parts, bounds, *q, axis):
        pri = self.primary(parts, bounds, *q, axis=axis)
        ok = self.get_ok(pri)

        def on_ok(_):
            return self.merge_ok(pri)

        def on_overflow(_):
            fb = self.fallback(parts, bounds,
                               *[q[i] for i in self.fb_args], axis=axis)
            return self.merge_fb(pri, fb)

        return jax.lax.cond(jnp.all(ok), on_ok, on_overflow, None), ok
