"""SpatialEngine: backward-compatible facade over the plan/executor API.

The engine's method-per-query-type surface (point_query, range_count,
range_query, circle_count, circle_query, knn, join_count) is kept for
existing callers, but every method now delegates to ONE
``core.executor.Executor`` dispatching declarative ``core.plan``
QuerySpecs — compilation, the executable cache, and the adaptive
sticky/escalation policy live there, once.

New code should target the plan API directly:

    from repro.core import Executor, RangeQuery, Knn
    ex = Executor(index, mesh=mesh)
    counts, vids, ok = ex.run(RangeQuery(), rects)
    d2, ids = ex.run(Knn(k=10), qx, qy)

``Executor.run`` (strict=False) is the serving path: steady-state
sticky hits execute a fused windowed+fallback program with zero
host-side syncs. The facade methods use strict=True, preserving the
pre-plan engine's host-checked escalation loop bit-for-bit (golden
parity suite: tests/test_executor_parity.py). Architecture notes:
DESIGN.md §9; query semantics: src/repro/core/plan.py.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.core.build import LearnedSpatialIndex
from repro.core.executor import Executor
from repro.core.plan import (CircleQuery, DeleteBatch, EngineConfig,
                             InsertBatch, Knn, PointQuery, RangeCount,
                             RangeQuery, SpatialJoin)

# compat re-exports: these lived here pre-plan; the local SPMD programs
# themselves moved to core/local_ops.py (import them from there)
from repro.core.local_ops import EMPTY_BOX, pad_partitions  # noqa: F401


class SpatialEngine:
    """Batched spatial query engine over a LearnedSpatialIndex.

    mesh=None -> single-device; otherwise partitions are sharded over
    ``part_axis`` (and query batches optionally over ``query_axis``).
    Thin facade: see module docstring and core/executor.py.
    """

    def __init__(self, index: LearnedSpatialIndex, mesh: Optional[Mesh] = None,
                 part_axis: str = "data", query_axis: Optional[str] = None,
                 config: Optional[EngineConfig] = None):
        self.executor = Executor(index, mesh=mesh, part_axis=part_axis,
                                 query_axis=query_axis, config=config)

    # executor state exposed for existing callers / introspection
    @property
    def index(self):
        return self.executor.index

    @property
    def cfg(self):
        return self.executor.cfg

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def parts(self):
        return self.executor.parts

    @property
    def bounds(self):
        return self.executor.bounds

    @property
    def spec(self):
        return self.executor.spec

    @property
    def backend(self):
        """Resolved kernel backend name ("xla" | "pallas")."""
        return self.executor.backend.name

    @property
    def density(self):
        return self.executor.density

    @property
    def n_total(self):
        return self.executor.n_total

    # -- plan API passthrough (the extension point) ----------------------

    def run(self, spec, *args, strict: bool = False):
        """Dispatch a QuerySpec (see core/plan.py) through the executor."""
        return self.executor.run(spec, *args, strict=strict)

    def run_batch(self, requests, strict: bool = False):
        return self.executor.run_batch(requests, strict=strict)

    # -- facade methods (pre-plan signatures, strict semantics) ----------

    def point_query(self, qx, qy):
        """Exact membership (paper §4.1): found (Q,) bool."""
        return self.executor.run(PointQuery(), qx, qy)

    def range_count(self, rects):
        """Exact in-rect counts (paper §4.2): (Q,) int32."""
        return self.executor.run(RangeCount(), rects)

    def range_query(self, rects, cap: Optional[int] = None):
        """Windowed materializing range query.

        Returns (counts, vids (Q, ncap) padded -1, ok). Falls back to a
        doubled cap on host when any window overflowed (exactness kept).
        """
        return self.executor.run(RangeQuery(cap=cap), rects, strict=True)

    def circle_count(self, cx, cy, r):
        """Circle range query via MBR + distance refine (paper Remark 2)."""
        return self.executor.run(CircleQuery(), cx, cy, r, strict=True)

    def circle_query(self, cx, cy, r):
        """Materializing circle query: (counts, vids padded -1, ok)."""
        return self.executor.run(CircleQuery(materialize=True),
                                 cx, cy, r, strict=True)

    def knn(self, qx, qy, k: int, mode: str = "pruned"):
        """Exact k nearest neighbours: (dist2 (Q,k), vid (Q,k))."""
        return self.executor.run(Knn(k=k, mode=mode), qx, qy,
                                 strict=True)

    def join_count(self, polys, n_edges, mode: str = "windowed"):
        """counts (PG,) of points contained in each polygon.

        polys: (PG, E, 2) padded vertex lists; n_edges: (PG,) int32.
        Polygons are broadcast (replicated) — the paper's |PG| << |D|
        case.
        """
        return self.executor.run(SpatialJoin(mode=mode), polys, n_edges,
                                 strict=True)

    # -- mutations (epoch-versioned mutable index, DESIGN.md §11) --------

    @property
    def epoch(self) -> int:
        """Mutation epoch of the resident index."""
        return self.executor.index.epoch

    def insert(self, xs, ys):
        """Batched insert into the per-partition delta buffers.
        Returns the assigned point ids (B,)."""
        return self.executor.run(InsertBatch(), xs, ys)

    def delete(self, xs, ys) -> int:
        """Batched delete by coordinate (tombstones every live copy).
        Returns the number of removed points."""
        return self.executor.run(DeleteBatch(), xs, ys)

    def refit(self, touched=None):
        """Compaction + spline re-fit of ``touched`` (default: every
        dirty) partitions. Returns the partition ids re-fit."""
        return self.executor.refit(touched)
