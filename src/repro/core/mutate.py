"""Batched index mutations: insert/delete absorption + per-partition
spline re-fit (paper's update story; DESIGN.md §11).

The mutable-index contract (``build.LearnedSpatialIndex``):

  insert   append to the target partition's DELTA BUFFER (capacity-
           padded slots; host grows the capacity when a batch would
           overflow — a static-shape change, so the executor bumps
           ``shape_epoch`` and evicts stale executables).
  delete   tombstone in place: the sorted key row is untouched (the
           fitted spline stays valid), coordinates are poisoned to
           ``PAD_COORD`` and the vid to -1 — every coordinate-refine
           scan on either kernel backend then excludes the slot with no
           extra masking. Deletes of still-buffered inserts poison the
           delta slot the same way.
  refit    ``refit_partitions(idx, touched)``: merge delta + drop
           tombstones and re-run the error-bounded spline fit (the
           scalar-carry scan, ``build.fit_partitions``) over ONLY the
           touched partition rows; untouched partitions keep their
           arrays bit-for-bit. After a full refit the index answers
           every query bitwise-identically to a fresh ``build_index``
           on the surviving point set (tests/test_updates.py).

All entry points are host-driven (like ``build_index``): shapes become
static per (batch size, capacity) so the jitted kernels cache like
query executables; the executor routes them through its executable
cache via ``plan.exec_key``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core.build import (LearnedSpatialIndex, PAD_COORD,
                              assign_partitions, fit_partitions,
                              probe_for)


def _pow2_at_least(n: int, floor: int) -> int:
    if max(n, floor) <= 0:
        return 0        # zero-capacity request: aux state only
    return max(floor, int(2 ** np.ceil(np.log2(max(n, 1)))))


@jax.jit
def row_max_runs(key_g, counts):
    """(P,) longest duplicate-key run per row (valid prefix only) —
    recovers the probe-sizing statistic for indexes that predate the
    mutable-state split (build_index stores it directly)."""
    p, n_pad = key_g.shape
    keys_f = K.keys_to_f32(key_g)
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    valid = idx[None, :] < counts[:, None]
    prev = jnp.concatenate(
        [jnp.full((p, 1), -1.0, jnp.float32), keys_f[:, :-1]], axis=1)
    first = valid & (keys_f != prev)
    start = jnp.where(first, idx[None, :], -1)
    last_start = jax.lax.cummax(start, axis=1)
    runlen = jnp.where(valid, idx[None, :] - last_start + 1, 0)
    return jnp.max(runlen, axis=1).astype(jnp.int32)


def with_delta_capacity(index: LearnedSpatialIndex, cap: int,
                        floor: int = 64) -> LearnedSpatialIndex:
    """Grow the per-partition delta buffer to hold >= ``cap`` slots.

    Returns the index unchanged when it already fits; otherwise pads
    the delta planes to the next power of two and bumps ``shape_epoch``
    (compiled programs bake the capacity into their shapes).
    """
    cur = index.delta_cap
    if index.delta_key is not None and cur >= cap:
        return index
    new_cap = _pow2_at_least(cap, floor)
    p = index.num_partitions
    sentinel = jnp.uint32(index.key_spec.sentinel)

    def grow(a, fill, dtype):
        fresh = jnp.full((p, new_cap), fill, dtype)
        if a is None or a.shape[1] == 0:
            return fresh
        return fresh.at[:, :a.shape[1]].set(a)

    return dataclasses.replace(
        index,
        delta_key=grow(index.delta_key, sentinel, jnp.uint32),
        delta_x=grow(index.delta_x, PAD_COORD, jnp.float32),
        delta_y=grow(index.delta_y, PAD_COORD, jnp.float32),
        delta_vid=grow(index.delta_vid, -1, jnp.int32),
        delta_count=(index.delta_count if index.delta_count is not None
                     else jnp.zeros((p,), jnp.int32)),
        dead=(index.dead if index.dead is not None
              else jnp.zeros((p,), jnp.int32)),
        max_run=(index.max_run if index.max_run is not None
                 else row_max_runs(index.key, index.count)),
        refit_gen=(index.refit_gen if index.refit_gen is not None
                   else jnp.zeros((p,), jnp.int32)),
        shape_epoch=index.shape_epoch + 1,
    )


def shrink_delta_capacity(index: LearnedSpatialIndex,
                          cap: int) -> LearnedSpatialIndex:
    """Inverse of ``with_delta_capacity`` for burst-grown buffers:
    slice the delta planes back down after compaction has emptied
    them, so one skewed insert burst does not tax every later query
    (and the index footprint) forever. The caller must have re-fit
    first — every buffered entry must fit the new capacity."""
    new_cap = _pow2_at_least(cap, 0)
    if new_cap >= index.delta_cap:
        return index
    if int(jnp.max(index.delta_count)) > new_cap:
        raise ValueError("shrink below live delta occupancy")
    return dataclasses.replace(
        index,
        delta_key=index.delta_key[:, :new_cap],
        delta_x=index.delta_x[:, :new_cap],
        delta_y=index.delta_y[:, :new_cap],
        delta_vid=index.delta_vid[:, :new_cap],
        shape_epoch=index.shape_epoch + 1,
    )


def assign_insert(index: LearnedSpatialIndex, xs, ys):
    """Partition ids for new points: first-match grid, miss -> overflow
    (identical semantics to the build-time assignment)."""
    boxes = index.part_bounds[:index.overflow]
    pid = assign_partitions(xs, ys, boxes)
    # assign_partitions returns boxes.shape[0] (== overflow) for misses
    return pid


# ---------------------------------------------------------------------------
# jitted mutation kernels (shapes static per batch size / capacity)
# ---------------------------------------------------------------------------

def scatter_inserts(dkey, dx, dy, dvid, dcount, pid, key, xs, ys, vids):
    """Append a batch into the delta planes. Caller guarantees capacity.

    The within-batch slot of each insert is its rank among same-
    partition predecessors (O(B^2) mask — update batches are small
    relative to the data plane), preserving arrival (= vid) order so a
    later stable merge reproduces the fresh-build tie order.
    """
    b = pid.shape[0]
    same = pid[None, :] == pid[:, None]                     # (B, B)
    before = jnp.tril(same, -1)
    rank = jnp.sum(before.astype(jnp.int32), axis=1)
    slot = dcount[pid] + rank
    return (dkey.at[pid, slot].set(key),
            dx.at[pid, slot].set(xs),
            dy.at[pid, slot].set(ys),
            dvid.at[pid, slot].set(vids),
            dcount.at[pid].add(1))


def apply_deletes(xp, yp, vidp, count, dxp, dyp, dvidp, dcount, dead,
                  qx, qy, pid1, pid2):
    """Tombstone every live copy of each (x, y) in its two candidate
    partitions (first-match grid + overflow), main plane AND delta.

    Returns the poisoned planes, the updated per-partition dead count,
    and the total number of removed points (a (,) int32).
    """
    n_pad = xp.shape[1]
    pids = jnp.stack([pid1, pid2], axis=1).reshape(-1)      # (2B,)
    qx2 = jnp.repeat(qx, 2)
    qy2 = jnp.repeat(qy, 2)
    posn = jnp.arange(n_pad, dtype=jnp.int32)

    rows_x = xp[pids]
    rows_y = yp[pids]
    rows_v = vidp[pids]
    m = ((rows_x == qx2[:, None]) & (rows_y == qy2[:, None]) &
         (rows_v >= 0) & (posn[None, :] < count[pids][:, None]))
    hit = jnp.zeros(xp.shape, jnp.int32).at[pids].max(
        m.astype(jnp.int32)) > 0
    newly = hit & (vidp >= 0)
    new_x = jnp.where(hit, PAD_COORD, xp)
    new_y = jnp.where(hit, PAD_COORD, yp)
    new_v = jnp.where(hit, -1, vidp)
    dead2 = dead + jnp.sum(newly.astype(jnp.int32), axis=1)
    removed = jnp.sum(newly.astype(jnp.int32))

    d_cap = dxp.shape[1]
    if d_cap:
        slot = jnp.arange(d_cap, dtype=jnp.int32)
        drx = dxp[pids]
        dry = dyp[pids]
        drv = dvidp[pids]
        dm = ((drx == qx2[:, None]) & (dry == qy2[:, None]) &
              (drv >= 0) & (slot[None, :] < dcount[pids][:, None]))
        dhit = jnp.zeros(dxp.shape, jnp.int32).at[pids].max(
            dm.astype(jnp.int32)) > 0
        dnew = dhit & (dvidp >= 0)
        dxp = jnp.where(dhit, PAD_COORD, dxp)
        dyp = jnp.where(dhit, PAD_COORD, dyp)
        dvidp = jnp.where(dhit, -1, dvidp)
        removed = removed + jnp.sum(dnew.astype(jnp.int32))

    return new_x, new_y, new_v, dxp, dyp, dvidp, dead2, removed


@partial(jax.jit, static_argnames=("sentinel",))
def merge_rows(key_r, x_r, y_r, vid_r, count_r,
               dkey_r, dx_r, dy_r, dvid_r, dcount_r, *, sentinel: int):
    """Compact k gathered partition rows: drop tombstones, merge delta.

    A stable sort over (main row ++ delta row) keys — tombstones and
    padding mapped to the sentinel so they sink to the tail — yields
    rows sorted by (key asc, vid asc): the main row already holds equal
    keys in vid order and delta vids are strictly newer, so stability
    reproduces the fresh-build layout bitwise.
    """
    n_pad = key_r.shape[1]
    sent = jnp.uint32(sentinel)
    posn = jnp.arange(n_pad, dtype=jnp.int32)
    alive_m = (vid_r >= 0) & (posn[None, :] < count_r[:, None])
    keym = jnp.where(alive_m, key_r, sent)
    xm = jnp.where(alive_m, x_r, PAD_COORD)
    ym = jnp.where(alive_m, y_r, PAD_COORD)
    vm = jnp.where(alive_m, vid_r, -1)

    d_cap = dkey_r.shape[1]
    if d_cap:
        slot = jnp.arange(d_cap, dtype=jnp.int32)
        alive_d = (dvid_r >= 0) & (slot[None, :] < dcount_r[:, None])
        keyc = jnp.concatenate(
            [keym, jnp.where(alive_d, dkey_r, sent)], axis=1)
        xc = jnp.concatenate(
            [xm, jnp.where(alive_d, dx_r, PAD_COORD)], axis=1)
        yc = jnp.concatenate(
            [ym, jnp.where(alive_d, dy_r, PAD_COORD)], axis=1)
        vc = jnp.concatenate([vm, jnp.where(alive_d, dvid_r, -1)], axis=1)
        n_alive = (jnp.sum(alive_m.astype(jnp.int32), axis=1) +
                   jnp.sum(alive_d.astype(jnp.int32), axis=1))
    else:
        keyc, xc, yc, vc = keym, xm, ym, vm
        n_alive = jnp.sum(alive_m.astype(jnp.int32), axis=1)

    order = jnp.argsort(keyc, axis=1, stable=True)
    new_key = jnp.take_along_axis(keyc, order, axis=1)[:, :n_pad]
    new_x = jnp.take_along_axis(xc, order, axis=1)[:, :n_pad]
    new_y = jnp.take_along_axis(yc, order, axis=1)[:, :n_pad]
    new_v = jnp.take_along_axis(vc, order, axis=1)[:, :n_pad]
    return new_key, new_x, new_y, new_v, n_alive.astype(jnp.int32)


# ---------------------------------------------------------------------------
# per-partition re-fit (host entry point, like build_index)
# ---------------------------------------------------------------------------

def grow_n_pad(index: LearnedSpatialIndex,
               new_n_pad: int) -> LearnedSpatialIndex:
    """Widen the data plane (rare: merged rows outgrew n_pad)."""
    new_n_pad = int(np.ceil(new_n_pad / 128) * 128)
    if new_n_pad <= index.n_pad:
        return index
    p = index.num_partitions
    extra = new_n_pad - index.n_pad

    def widen(a, fill, dtype):
        pad = jnp.full((p, extra), fill, dtype)
        return jnp.concatenate([a, pad], axis=1)

    return dataclasses.replace(
        index,
        key=widen(index.key, jnp.uint32(index.key_spec.sentinel),
                  jnp.uint32),
        x=widen(index.x, PAD_COORD, jnp.float32),
        y=widen(index.y, PAD_COORD, jnp.float32),
        vid=widen(index.vid, -1, jnp.int32),
        shape_epoch=index.shape_epoch + 1,
    )


def dirty_partitions(index: LearnedSpatialIndex) -> np.ndarray:
    """Partition ids with buffered inserts or tombstones (host view)."""
    if index.delta_count is None:
        return np.zeros((0,), np.int32)
    dirty = (np.asarray(index.delta_count) > 0)
    if index.dead is not None:
        dirty |= np.asarray(index.dead) > 0
    return np.nonzero(dirty)[0].astype(np.int32)


def delta_occupancy(index: LearnedSpatialIndex) -> np.ndarray:
    """Per-partition dirtiness fraction: (buffered + tombstoned) over
    live points — the executor's compaction/re-fit trigger."""
    p = index.num_partitions
    if index.delta_count is None:
        return np.zeros((p,), np.float64)
    dcount = np.asarray(index.delta_count, np.int64)
    dead = (np.asarray(index.dead, np.int64) if index.dead is not None
            else np.zeros((p,), np.int64))
    count = np.asarray(index.count, np.int64)
    live = np.maximum(count - dead + dcount, 1)
    return (dcount + dead) / live


def refit_partitions(index: LearnedSpatialIndex, touched):
    """Merge delta + drop tombstones + re-fit the spline for ONLY the
    ``touched`` partitions. Bumps ``epoch`` and the touched rows'
    ``refit_gen``; untouched partition arrays are preserved bitwise.

    Returns the new index. Capacity growth (n_pad, knot width, probe)
    happens here when the merged rows outgrow the current statics, each
    bumping ``shape_epoch``.
    """
    touched = np.unique(np.asarray(touched, np.int32))
    if touched.size == 0:
        return index
    if index.delta_key is None:
        index = with_delta_capacity(index, 0, floor=0)
    t = jnp.asarray(touched)

    # -- host sizing: merged rows must fit the data plane -------------
    dcountv = np.asarray(index.delta_count)
    deadv = np.asarray(index.dead)
    alive_delta = np.asarray(
        jnp.sum((index.delta_vid >= 0).astype(jnp.int32), axis=1)
        if index.delta_cap else jnp.zeros_like(index.delta_count))
    new_counts = (np.asarray(index.count) - deadv + alive_delta)[touched]
    if new_counts.max(initial=0) > index.n_pad:
        index = grow_n_pad(index, int(new_counts.max()))

    key_r, x_r, y_r, vid_r, cnt = merge_rows(
        index.key[t], index.x[t], index.y[t], index.vid[t],
        index.count[t], index.delta_key[t], index.delta_x[t],
        index.delta_y[t], index.delta_vid[t], index.delta_count[t],
        sentinel=index.key_spec.sentinel)

    # -- re-fit: the same scalar-carry scan the build uses ------------
    m = index.knot_keys.shape[1]
    while True:
        fit = fit_partitions(key_r, cnt, eps=index.eps, m_pad=m,
                             radix_bits=index.radix_bits)
        if not bool(jnp.any(fit["overflow"])):
            break
        if m >= index.n_pad:
            raise RuntimeError("spline knot capacity exceeded at n_pad")
        m = min(m * 2, index.n_pad)
    if m != index.knot_keys.shape[1]:
        extra = m - index.knot_keys.shape[1]
        p = index.num_partitions
        index = dataclasses.replace(
            index,
            knot_keys=jnp.concatenate(
                [index.knot_keys,
                 jnp.full((p, extra), 3.4e38, jnp.float32)], axis=1),
            knot_pos=jnp.concatenate(
                [index.knot_pos, jnp.zeros((p, extra), jnp.float32)],
                axis=1),
            shape_epoch=index.shape_epoch + 1)

    # -- scatter the compacted rows + fresh fit back ------------------
    sentinel = jnp.uint32(index.key_spec.sentinel)
    d_cap = index.delta_cap
    new = dataclasses.replace(
        index,
        key=index.key.at[t].set(key_r),
        x=index.x.at[t].set(x_r),
        y=index.y.at[t].set(y_r),
        vid=index.vid.at[t].set(vid_r),
        count=index.count.at[t].set(cnt),
        knot_keys=index.knot_keys.at[t].set(fit["knot_keys"]),
        knot_pos=index.knot_pos.at[t].set(fit["knot_pos"]),
        n_knots=index.n_knots.at[t].set(fit["n_knots"]),
        radix_table=index.radix_table.at[t].set(fit["radix_table"]),
        radix_kmin=index.radix_kmin.at[t].set(fit["radix_kmin"]),
        radix_scale=index.radix_scale.at[t].set(fit["radix_scale"]),
        delta_key=index.delta_key.at[t].set(
            jnp.full((t.shape[0], d_cap), sentinel, jnp.uint32)),
        delta_x=index.delta_x.at[t].set(
            jnp.full((t.shape[0], d_cap), PAD_COORD, jnp.float32)),
        delta_y=index.delta_y.at[t].set(
            jnp.full((t.shape[0], d_cap), PAD_COORD, jnp.float32)),
        delta_vid=index.delta_vid.at[t].set(
            jnp.full((t.shape[0], d_cap), -1, jnp.int32)),
        delta_count=index.delta_count.at[t].set(0),
        dead=index.dead.at[t].set(0),
        max_run=index.max_run.at[t].set(fit["max_run"].astype(jnp.int32))
        if index.max_run is not None
        else None,
        refit_gen=index.refit_gen.at[t].add(1),
        epoch=index.epoch + 1,
    )

    # -- probe refresh: duplicate runs may have grown ------------------
    # Same sizing rule the build uses (probe_for over the GLOBAL max
    # run), so a fully-refit index carries exactly the probe a fresh
    # build of the surviving points would: inserts that lengthen a
    # duplicate run widen the window (a static shape change — exact
    # results are probe-independent, so only compile caches notice).
    if new.max_run is not None:
        need = probe_for(new.eps, int(jnp.max(new.max_run)), new.n_pad)
        if need > new.probe:
            new = dataclasses.replace(
                new, probe=need, shape_epoch=new.shape_epoch + 1)
    return new


def verify_eps(index: LearnedSpatialIndex, pid: int) -> float:
    """Max |S(key) - first_occurrence_rank| over one partition's keys.

    The greedy corridor guarantees <= 2*eps at interpolation (a
    corridor restart anchors at the PREVIOUS data point, itself up to
    eps off the fitted line — the same bound a fresh build exhibits).
    Host-side diagnostic; tests re-verify it per touched partition
    after every re-fit, pinning that updates never degrade the fit
    below what ``build_index`` would produce."""
    from repro.core import spline as S
    cnt = int(index.count[pid])
    if cnt == 0:
        return 0.0
    keys_f = K.keys_to_f32(index.key[pid, :cnt])
    first = np.concatenate([[True], np.asarray(keys_f[1:] != keys_f[:-1])])
    pred = S.spline_predict(index.knot_keys[pid], index.knot_pos[pid],
                            index.n_knots[pid], keys_f)
    pos = np.arange(cnt, dtype=np.float32)
    return float(np.max(np.abs(np.asarray(pred)[first] - pos[first])))
