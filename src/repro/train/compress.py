"""Gradient compression for cross-DCN (pod-axis) all-reduce.

int8 block-quantized all-reduce with ERROR FEEDBACK: each worker keeps
the quantization residual and folds it into the next step's gradient, so
compression error accumulates to zero over time (EF-SGD guarantee). At
1000+-node scale the pod-axis all-reduce crosses data-center links; 4x
byte reduction there is the paper-agnostic distributed-optimization trick
this framework ships (opt-in: TrainStep(compress_pod_grads=True) wiring
shown in launch/train.py --compress).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(g) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape)


def _block_scales(g):
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    return blocks, jnp.maximum(
        jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0, 1e-12)


def ef_compress_grads(grads, residuals, axis_name):
    """Error-feedback int8-compressed gradient sync (tree-wise).

    Protocol (per block): share the MAX scale across the axis first
    (pmax, tiny payload), quantize everyone against the shared scale,
    then psum the int values — the integer sum is exactly the sum of the
    quantized contributions, so the only error is local quantization,
    which error feedback folds into the next step (EF-SGD guarantee).

    Returns (synced_mean_grads, new_residuals).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        blocks, scale = _block_scales(g32)
        smax = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(blocks / smax), -127, 127)
        recon = (q * smax).reshape(-1)[: g32.size].reshape(g32.shape)
        new_r = g32 - recon
        q32 = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = (q32 * smax).reshape(-1)[: g32.size].reshape(g32.shape)
        n = jax.lax.psum(1, axis_name)
        return (ssum / n).astype(g.dtype), new_r

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    gs = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    rs = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    return gs, rs


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
