"""Jitted train step with mesh sharding, microbatching, and remat.

Parallelism (DESIGN.md §8): batch over (pod, data); params FSDP x TP over
(data, model). XLA SPMD then emits, per layer: all-gather of the FSDP
weight shard (overlappable with the previous layer's compute), local
matmuls, reduce-scatter of weight grads over `data`, all-reduce of the
(pod-replicated) gradient over `pod` — the hierarchical DP pattern that
keeps cross-DCN traffic to one all-reduce per step at 1000+-node scale.

Microbatching: lax.scan over microbatch slices accumulating f32 grads —
keeps activation peaks ~1/n_micro while the optimizer sees the full
global batch.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import (MeshRules, batch_specs, param_specs, use_mesh)
from repro.train.optimizer import (adamw_update, clip_by_global_norm,
                                   cosine_schedule)


def make_train_step(model, *, mesh=None, rules: Optional[MeshRules] = None,
                    n_micro: int = 1, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    max_grad_norm: float = 1.0, donate: bool = True,
                    bf16_weights: bool = False):
    """Returns (step_fn, shard_in) where step_fn(params, opt, batch) ->
    (params, opt, metrics).

    bf16_weights: cast the param tree to bf16 ONCE per step, outside the
    microbatch loop (gradients flow to the bf16 tree; AdamW keeps the
    f32 master). FSDP weight all-gathers then move bf16, not f32 —
    halving the collective term — and the per-use f32->bf16 converts
    inside every layer disappear (§Perf iteration).
    """
    rules = rules or MeshRules()

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def constrain_like_params(g):
        """Pin gradient sharding to the (FSDP x TP) param layout so the
        per-microbatch gradient reduction lowers to reduce-scatter, not
        a full-tensor all-reduce (measured 4.3 TB/chip/step on dbrx
        without this — EXPERIMENTS.md §Perf iteration 3)."""
        if mesh is None:
            return g
        specs = param_specs(mesh, rules, g)
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, specs)

    def compute_grads(params, batch):
        if n_micro == 1:
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            return loss, constrain_like_params(g)
        b = max(leaf.shape[0] for leaf in
                jax.tree_util.tree_leaves(batch) if leaf.ndim >= 1)
        assert b % n_micro == 0
        mb = b // n_micro
        sl = jax.tree_util.tree_map(
            lambda a: a.reshape((n_micro, mb) + a.shape[1:])
            if a.ndim >= 1 and a.shape[0] == b else
            jnp.broadcast_to(a, (n_micro,) + a.shape), batch)

        def micro(carry, mbatch):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            g = constrain_like_params(g)
            acc_g = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc_g, g)
            return (acc_loss + l, constrain_like_params(acc_g)), None

        zero_g = constrain_like_params(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), zero_g),
                                        sl)
        scale = 1.0 / n_micro
        return loss * scale, jax.tree_util.tree_map(
            lambda g: g * scale, grads)

    def step(params, opt_state, batch):
        with use_mesh(mesh, rules):
            if bf16_weights:
                params_c = jax.tree_util.tree_map(
                    lambda p: p.astype(jnp.bfloat16)
                    if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                    params)
                loss, grads = compute_grads(params_c, batch)
            else:
                loss, grads = compute_grads(params, batch)
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
            lr = cosine_schedule(opt_state.step, peak_lr=peak_lr,
                                 warmup=warmup, total=total_steps)
            params, opt_state = adamw_update(grads, opt_state, params,
                                             lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    import types
    if mesh is None:
        return types.SimpleNamespace(
            jit=jax.jit(step, donate_argnums=(0, 1) if donate else ()),
            raw=step, shard_in=None)

    def shard_in(params, opt_state, batch):
        from repro.train.optimizer import AdamWState
        params = jax.device_put(params, param_specs(mesh, rules, params))
        opt_state = AdamWState(
            step=jax.device_put(opt_state.step),
            m=jax.device_put(opt_state.m,
                             param_specs(mesh, rules, opt_state.m)),
            v=jax.device_put(opt_state.v,
                             param_specs(mesh, rules, opt_state.v)))
        batch = jax.device_put(batch, batch_specs(mesh, rules, batch))
        return params, opt_state, batch

    return types.SimpleNamespace(
        jit=jax.jit(step, donate_argnums=(0, 1) if donate else ()),
        raw=step, shard_in=shard_in)
