from repro.train.optimizer import (  # noqa: F401
    AdamWState, adamw_init, adamw_update, cosine_schedule, clip_by_global_norm,
)
from repro.train.step import make_train_step  # noqa: F401
from repro.train.loop import train_loop, TrainLoopConfig  # noqa: F401
