"""Fault-tolerant training loop.

Features (exercised by tests/test_fault.py):
  * auto-resume: restores params/opt/data-cursor from the newest valid
    checkpoint (a killed job restarts bit-exact).
  * step-atomic async checkpointing every `ckpt_every` steps.
  * straggler watchdog: EMA of step wall-time; steps slower than
    `straggler_factor` x EMA are logged and counted — on a real fleet this
    feeds the backup-worker re-dispatch; here it drives metrics + tests.
  * crash injection (`crash_at_step`) for restart tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import restore_or_init, save_checkpoint
from repro.data.tokens import TokenPipeline
from repro.train.optimizer import adamw_init


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    crash_at_step: Optional[int] = None   # fault-injection (tests)
    async_ckpt: bool = True


class InjectedCrash(RuntimeError):
    pass


def train_loop(model, step_obj, pipeline: TokenPipeline,
               loop_cfg: TrainLoopConfig, rng=None,
               log_fn: Callable[[str], None] = print):
    """Returns (params, opt_state, history dict)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def fresh():
        params = model.init(rng)
        return {"params": params, "opt": adamw_init(params)}

    start_step = 0
    if loop_cfg.ckpt_dir:
        state, start_step = restore_or_init(loop_cfg.ckpt_dir, fresh)
        if start_step:
            log_fn(f"[resume] restored step {start_step} from "
                   f"{loop_cfg.ckpt_dir}")
    else:
        state = fresh()
    params, opt = state["params"], state["opt"]
    if step_obj.shard_in is not None:
        params, opt, _ = step_obj.shard_in(params, opt,
                                           next(TokenPipeline(
                                               pipeline.cfg, pipeline.batch,
                                               pipeline.seq)))
    pipeline.skip_to(start_step)

    history = {"loss": [], "stragglers": 0, "step_times": []}
    ema = None
    pending = None
    for step in range(start_step, loop_cfg.steps):
        batch = next(pipeline)
        t0 = time.perf_counter()
        params, opt, metrics = step_obj.jit(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        history["loss"].append(loss)
        history["step_times"].append(dt)
        if ema is not None and dt > loop_cfg.straggler_factor * ema:
            history["stragglers"] += 1
            log_fn(f"[watchdog] straggler step {step}: {dt*1e3:.1f}ms "
                   f"(ema {ema*1e3:.1f}ms)")
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt

        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"({dt*1e3:.0f} ms)")

        done = step + 1
        if loop_cfg.ckpt_dir and (done % loop_cfg.ckpt_every == 0 or
                                  done == loop_cfg.steps):
            if pending is not None:
                pending.join()
            pending = save_checkpoint(
                loop_cfg.ckpt_dir, done,
                {"params": params, "opt": opt},
                meta={"arch": pipeline.cfg.name},
                async_write=loop_cfg.async_ckpt)

        if loop_cfg.crash_at_step is not None and \
                done == loop_cfg.crash_at_step:
            if pending is not None:
                pending.join()
            raise InjectedCrash(f"injected crash after step {done}")

    if pending is not None:
        pending.join()
    return params, opt, history
