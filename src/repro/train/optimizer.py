"""AdamW (decoupled weight decay) + gradient clipping + LR schedules.

Hand-rolled (no optax dependency): states are plain pytrees so the
checkpoint layer and the sharding rules treat them exactly like params
(m/v inherit the param sharding -> optimizer state is fully sharded,
ZeRO-style, over fsdp x tp).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * (g * g)
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    params2 = jax.tree_util.tree_map(lambda o: o[0], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
    m2 = jax.tree_util.tree_map(lambda o: o[1], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree_util.tree_map(lambda o: o[2], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    return params2, AdamWState(step=step, m=m2, v=v2)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), gn


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    t = step.astype(jnp.float32)
    warm = peak_lr * t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(t < warmup, warm, cos)
