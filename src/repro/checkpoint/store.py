"""Step-atomic sharded checkpoints with auto-resume and elastic reshard.

Layout:   <dir>/step_00001234/
            arrays.npz          flat {path -> np.ndarray}
            manifest.json       step, keys, shapes, dtypes, user meta
Written to step_X.tmp-<pid> then os.rename'd — a crash mid-write never
corrupts the latest valid checkpoint (restore scans for the newest
directory whose manifest verifies). Optional background-thread writes
overlap checkpoint I/O with the next training steps.

Elastic reshard: arrays are stored UNSHARDED (gathered); `load` re-places
them under whatever mesh/sharding the *restoring* job uses, so a job may
resume on a different topology (e.g. 256 -> 512 chips) — mesh shape is
recorded but not required to match.

On a real multi-host pod each host writes its own address-able shards;
the single-process container collapses that to one file (noted in
DESIGN.md §8).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def pstr(kp):
        out = []
        for k in kp:
            out.append(str(getattr(k, "key", getattr(k, "idx", k))))
        return "/".join(out)

    return {pstr(kp): np.asarray(jax.device_get(v)) for kp, v in flat}


def _unflatten_into(tree_like, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)

    def pstr(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    leaves = []
    for kp, proto in paths:
        key = pstr(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {proto.shape}")
        leaves.append(arr.astype(proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree, meta: Optional[dict]
                    = None, async_write: bool = False):
    """Atomically persist `tree` for `step`. Returns join() handle."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)   # gather BEFORE returning (donation safety)

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + f".tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            os.rename(final, final + ".old")
        os.rename(tmp, final)
        old = final + ".old"
        if os.path.exists(old):
            import shutil
            shutil.rmtree(old)

    if async_write:
        t = threading.Thread(target=write, daemon=False)
        t.start()
        return t
    write()
    return None


def _valid_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or ".tmp" in name or \
                name.endswith(".old"):
            continue
        man = os.path.join(ckpt_dir, name, "manifest.json")
        arr = os.path.join(ckpt_dir, name, "arrays.npz")
        if os.path.exists(man) and os.path.exists(arr):
            try:
                with open(man) as f:
                    steps.append(int(json.load(f)["step"]))
            except Exception:
                continue
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int, tree_like,
                    sharding_tree=None):
    """Load into the structure of `tree_like`; optionally re-place with
    `sharding_tree` (elastic reshard to the current mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(tree_like, flat)
    if sharding_tree is not None:
        tree = jax.device_put(tree, sharding_tree)
    return tree


def restore_or_init(ckpt_dir: str, init_fn: Callable[[], Any],
                    sharding_tree=None):
    """Auto-resume: newest valid checkpoint, else fresh init.

    Returns (tree, start_step)."""
    step = latest_step(ckpt_dir)
    if step is None:
        return init_fn(), 0
    proto = jax.eval_shape(init_fn)
    tree = load_checkpoint(ckpt_dir, step, proto, sharding_tree)
    return tree, step
