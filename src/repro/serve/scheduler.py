"""Streaming serve scheduler: request queue, adaptive micro-batching,
and off-hot-path maintenance (DESIGN.md §12).

``SpatialServeSession`` is call-and-wait: one caller, one ``submit``,
one dispatch. The traffic shape LiLIS targets — many small concurrent
point/range/circle/kNN requests plus a live ingest stream — needs the
same front door production inference stacks use: a request queue
drained by a background worker that COALESCES concurrent requests into
micro-batches for the warm fused executables, and defers maintenance
to idle time. This module is that front door:

  submit(spec, *args) -> Ticket      non-blocking; resolves when the
                                     micro-batch that carried the
                                     request completes on device
  drain()                            deterministic synchronous pump
                                     (test mode / start=False)
  request_maintain() -> Ticket       explicit maintenance barrier

Scheduling rules (the invariants tests/test_scheduler*.py pin):

  - FIFO with write barriers: requests are processed in arrival
    order; reads between two writes may be batched together (reads
    commute), but no read is ever hoisted across a write that was
    enqueued before it. A read enqueued after an ``InsertBatch`` /
    ``DeleteBatch`` therefore always observes that write's epoch
    (``Ticket.epoch`` carries the read-your-writes token).
  - Adaptive micro-batching: concurrent reads with the same spec (and
    concat-compatible arg shapes) coalesce along the query axis, up to
    a per-spec cap derived from the MEASURED wide-batch columns in
    ``BENCH_quick.json`` (``micro_batch_caps``): specs whose q=256
    column is cheaper per query coalesce wide; specs with inverted
    wide-batch scaling (the ROADMAP kNN/circle_mat blowup) stay at the
    narrow measured batch. Batch widths are padded to power-of-two
    buckets by repeating row 0 (a real, resolvable query — the
    query-shard pad/unpad precedent), so the compiled-executable count
    stays logarithmic in ``serve_max_batch`` and results stay
    bitwise-identical to serial ``submit()``.
  - Consecutive ``InsertBatch`` writes merge into one update dispatch
    (the ingest-stream fast path); the assigned vids are routed back
    per request. Deletes return one aggregate count and never merge.
  - ``maintain()`` (sticky re-tune + occupancy-triggered compaction)
    runs ONLY when the queue is idle — never between queued requests —
    or through an explicit ``request_maintain()`` barrier. The event
    log records the queue length at every maintenance run;
    ``stats()["maintain_busy"]`` must stay 0.

Thread model: ONE worker thread owns every executor dispatch
(``Executor`` is additionally locked, core/executor.py, so direct
``session.submit`` calls may race the scheduler safely). With
``start=False`` no thread is created and ``drain()`` pumps the same
batch-forming code synchronously — the deterministic mode the
coalescing/ordering tests and the traffic benchmark's bitwise parity
phase use.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict, deque
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import Executor
from repro.core.plan import (CircleQuery, EngineConfig, InsertBatch, Knn,
                             PointQuery, QuerySpec, RangeCount,
                             RangeQuery, SpatialJoin, UpdateSpec)


def bench_spec_name(spec: QuerySpec) -> str:
    """The BENCH_quick.json spec-column name for a QuerySpec."""
    if isinstance(spec, PointQuery):
        return "point"
    if isinstance(spec, RangeCount):
        return "range_count"
    if isinstance(spec, RangeQuery):
        return "range"
    if isinstance(spec, CircleQuery):
        return "circle_mat" if spec.materialize else "circle"
    if isinstance(spec, Knn):
        return f"knn{spec.k}"
    if isinstance(spec, SpatialJoin):
        return "join"
    return spec.kind


def micro_batch_caps(bench: Union[str, dict, None], backend: str,
                     cfg: EngineConfig) -> dict:
    """Per-spec micro-batch caps from the measured wide-batch columns.

    The quick bench's ``steady_us_per_q`` (narrow) vs
    ``steady_us_per_q_b256`` (wide) columns measure whether coalescing
    PAYS for each spec on each backend: when the wide column is no
    slower per query, the spec coalesces up to ``cfg.serve_max_batch``;
    when inverted (kNN / circle_mat wide-batch blowup, ROADMAP), the
    cap falls back to the narrow measured batch so the scheduler never
    forms batches the measurements say are slower per query. Missing
    file / columns -> empty dict (callers default to serve_max_batch).
    """
    if isinstance(bench, str):
        try:
            with open(bench) as f:
                bench = json.load(f)
        except (OSError, ValueError):
            return {}
    if not isinstance(bench, dict):
        return {}
    br = (bench.get("backends") or {}).get(backend) or bench
    narrow = max(int(bench.get("bench_q", 16)), 1)
    wide_b = int(bench.get("bench_q_wide", cfg.serve_max_batch))
    caps = {}
    for name, s in (br.get("specs") or {}).items():
        base = s.get("steady_us_per_q")
        wide = s.get("steady_us_per_q_b256")
        if base is None or wide is None:
            continue
        caps[name] = wide_b if wide <= base else narrow
    return caps


def _bucket(n: int) -> int:
    """Next power-of-two batch width (bounded executable variants)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class Ticket:
    """Future for one scheduled request.

    ``result()`` blocks until the micro-batch that carried the request
    completed on device. After completion:

      ``epoch``    the index mutation epoch the request observed
                   (reads) or produced (writes) — the read-your-writes
                   barrier token;
      ``batched``  the coalesced query width of the dispatch it rode
                   in (tests assert coalescing actually happened).
    """

    __slots__ = ("spec", "epoch", "batched", "_done", "_result", "_exc")

    def __init__(self, spec):
        self.spec = spec
        self.epoch: Optional[int] = None
        self.batched = 0
        self._done = threading.Event()
        self._result = None
        self._exc = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.spec!r} not completed "
                               f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def _resolve(self, result, epoch: int, batched: int):
        self._result = result
        self.epoch = epoch
        self.batched = batched
        self._done.set()

    def _fail(self, exc: BaseException):
        self._exc = exc
        self._done.set()


class _Request:
    __slots__ = ("kind", "spec", "args", "qlen", "sig", "ticket")

    def __init__(self, kind, spec, args, qlen, sig, ticket):
        self.kind = kind          # "read" | "write" | "maintain"
        self.spec = spec
        self.args = args
        self.qlen = qlen
        self.sig = sig
        self.ticket = ticket


class SpatialScheduler:
    """Queue + batch former + worker over one (locked) Executor."""

    def __init__(self, executor: Executor,
                 bench: Union[str, dict, None] = None,
                 start: bool = True):
        self.ex = executor
        self.cfg = executor.cfg
        if bench is None:
            bench = os.environ.get("BENCH_QUICK_OUT", "BENCH_quick.json")
        self.caps = micro_batch_caps(bench, executor.backend.name,
                                     self.cfg)
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._stopping = False
        self._inflight = 0        # popped but not yet resolved
        self.events: deque = deque(maxlen=4096)
        self.submitted = 0
        self.reads = 0            # queries dispatched via read batches
        self.read_batches = 0     # coalesced read dispatches
        self.max_batch = 0        # widest coalesced read batch (queries)
        self.writes = 0           # write requests applied
        self.write_merges = 0     # insert requests merged into a run
        self.maintain_runs = 0
        self.maintain_busy = 0    # maintain with a non-empty queue (BAD)
        self._thread = None
        if start:
            self._thread = threading.Thread(
                target=self._worker, daemon=True,
                name="spatial-serve-scheduler")
            self._thread.start()

    # -- submission ------------------------------------------------------

    def submit(self, spec: QuerySpec, *args) -> Ticket:
        """Enqueue one request; returns immediately with its Ticket."""
        if not isinstance(spec, QuerySpec):
            raise TypeError(f"expected a QuerySpec, got {spec!r}")
        if len(args) != spec.n_args:
            raise TypeError(f"{type(spec).__name__} takes {spec.n_args} "
                            f"data arguments, got {len(args)}")
        args = tuple(a if hasattr(a, "shape") else np.asarray(a)
                     for a in args)
        qlen = int(args[0].shape[0]) if args else 0
        # coalescing signature: same spec (frozen dataclass equality ==
        # same compiled family) AND concat-compatible trailing shapes
        sig = (spec,) + tuple((a.shape[1:], str(a.dtype)) for a in args)
        kind = "write" if isinstance(spec, UpdateSpec) else "read"
        ticket = Ticket(spec)
        req = _Request(kind, spec, args, qlen, sig, ticket)
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is closed")
            while (self._thread is not None
                   and len(self._q) >= self.cfg.serve_queue_depth):
                self._cv.wait(0.005)     # backpressure
            self._q.append(req)
            self.submitted += 1
            self._cv.notify_all()
        return ticket

    def request_maintain(self) -> Ticket:
        """Enqueue an explicit maintenance barrier (arrival order —
        after everything already queued). Resolves with maintain()'s
        {moved} dict; long-lived servers use this to trigger re-tune /
        compaction at a chosen moment without stopping the scheduler."""
        ticket = Ticket(None)
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is closed")
            self._q.append(_Request("maintain", None, (), 0, None,
                                    ticket))
            self.submitted += 1
            self._cv.notify_all()
        return ticket

    # -- batch forming ---------------------------------------------------

    def _cap(self, spec: QuerySpec) -> int:
        cap = self.caps.get(bench_spec_name(spec),
                            self.cfg.serve_max_batch)
        return max(1, min(self.cfg.serve_max_batch, cap))

    def _pop(self, timeout: Optional[float] = None):
        with self._cv:
            if not self._q and timeout:
                self._cv.wait(timeout)
            if self._q:
                self._inflight += 1
                self._cv.notify_all()    # free a backpressured submit
                return self._q.popleft()
            return None

    def _pop_merge(self, req: _Request, total: int):
        """Pop the next queued item iff it merges with an InsertBatch
        run: same spec + signature, and the merged width stays within
        serve_max_batch."""
        with self._cv:
            if (self._q and self._q[0].kind == "write"
                    and self._q[0].sig == req.sig
                    and total + self._q[0].qlen
                    <= self.cfg.serve_max_batch):
                self._inflight += 1
                return self._q.popleft()
        return None

    def _finish(self, n: int):
        with self._cv:
            self._inflight -= n
            self._cv.notify_all()

    def _form_and_run(self, straggler_wait: float = 0.0) -> bool:
        """Drain the queue once: FIFO order, reads coalesced between
        write barriers. Returns whether any request was processed."""
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        sizes: dict = {}
        did = False

        def flush(sig):
            reqs = groups.pop(sig)
            sizes.pop(sig)
            self._dispatch_reads(reqs)

        def flush_all():
            while groups:
                flush(next(iter(groups)))

        while True:
            req = self._pop()
            if req is None and groups and straggler_wait:
                # a partial batch exists: wait briefly for stragglers
                req = self._pop(timeout=straggler_wait)
            if req is None:
                break
            did = True
            if req.kind == "read":
                groups.setdefault(req.sig, []).append(req)
                sizes[req.sig] = sizes.get(req.sig, 0) + req.qlen
                if sizes[req.sig] >= self._cap(req.spec):
                    flush(req.sig)
            elif req.kind == "maintain":
                flush_all()              # barrier: order preserved
                self._maintain(ticket=req.ticket)
            else:
                flush_all()              # write barrier
                run, total = [req], req.qlen
                if isinstance(req.spec, InsertBatch):
                    while True:
                        nxt = self._pop_merge(req, total)
                        if nxt is None:
                            break
                        run.append(nxt)
                        total += nxt.qlen
                self._dispatch_write(run, total)
        flush_all()
        return did

    # -- dispatch --------------------------------------------------------

    def _dispatch_reads(self, reqs):
        spec = reqs[0].spec
        total = sum(r.qlen for r in reqs)
        width = _bucket(total)
        pad = width - total
        try:
            if len(reqs) == 1 and pad == 0:
                args = reqs[0].args
            else:
                # concat along the query axis; pad to the bucket width
                # by repeating row 0 (a real, resolvable query — can
                # never trip the adaptive ok flags; the qshard pad
                # precedent). Padding keeps the executable count
                # logarithmic instead of one program per arrival width.
                cols = zip(*(r.args for r in reqs))
                args = tuple(jnp.concatenate(c, axis=0) for c in cols)
                if pad:
                    args = tuple(jnp.concatenate(
                        [a, jnp.repeat(a[:1], pad, axis=0)], axis=0)
                        for a in args)
            out = self.ex.run(spec, *args)
            jax.block_until_ready(out)
        except Exception as e:           # route the failure per request
            for r in reqs:
                r.ticket._fail(e)
            self._finish(len(reqs))
            return
        epoch = self.ex.epoch
        lo = 0
        for r in reqs:
            if len(reqs) == 1 and pad == 0:
                res = out
            else:
                hi = lo + r.qlen
                res = jax.tree_util.tree_map(lambda a: a[lo:hi], out)
            r.ticket._resolve(res, epoch, total)
            lo += r.qlen
        self.reads += total
        self.read_batches += 1
        self.max_batch = max(self.max_batch, total)
        self.events.append(("batch", bench_spec_name(spec), total,
                            width, len(reqs)))
        self._finish(len(reqs))

    def _dispatch_write(self, run, total):
        spec = run[0].spec
        try:
            if len(run) == 1:
                out = self.ex.run(spec, *run[0].args)
            else:                        # merged InsertBatch stream
                xs = jnp.concatenate([r.args[0] for r in run], axis=0)
                ys = jnp.concatenate([r.args[1] for r in run], axis=0)
                out = self.ex.run(spec, xs, ys)
                self.write_merges += len(run) - 1
        except Exception as e:
            for r in run:
                r.ticket._fail(e)
            self._finish(len(run))
            return
        epoch = self.ex.epoch            # the epoch this write produced
        lo = 0
        for r in run:
            res = out if len(run) == 1 else out[lo:lo + r.qlen]
            r.ticket._resolve(res, epoch, total)
            lo += r.qlen
        self.writes += len(run)
        self.events.append(("write", spec.kind, total, len(run)))
        self._finish(len(run))

    def _maintain(self, ticket: Optional[Ticket] = None,
                  idle: bool = False):
        with self._cv:
            qlen = len(self._q)
        moved = self.ex.maintain()
        self.maintain_runs += 1
        if qlen:
            self.maintain_busy += 1      # should never happen on idle
        self.events.append(("maintain", qlen, bool(moved), idle))
        if ticket is not None:
            ticket._resolve(moved, self.ex.epoch, 0)
            self._finish(1)

    # -- worker / pumping ------------------------------------------------

    def _worker(self):
        straggler = self.cfg.serve_coalesce_us / 1e6
        while True:
            with self._cv:
                while not self._q and not self._stopping:
                    self._cv.wait(0.05)
                if self._stopping and not self._q:
                    return
            self._form_and_run(straggler_wait=straggler)
            # idle maintenance: the queue just drained — run deferred
            # re-tuning / compaction NOW, never between queued requests
            with self._cv:
                idle = not self._q and not self._stopping
            if (idle and self.cfg.serve_idle_maintain
                    and self.ex.maintenance_due()):
                self._maintain(idle=True)

    def drain(self, timeout: float = 60.0):
        """Process everything queued. With start=False this runs the
        batch former synchronously on the calling thread (then idle
        maintenance) — the deterministic test mode. With a live worker
        it blocks until the queue and in-flight work are empty."""
        if self._thread is not None:
            deadline = time.monotonic() + timeout
            while True:
                with self._cv:
                    if not self._q and self._inflight == 0:
                        return
                    self._cv.wait(0.005)
                if time.monotonic() > deadline:
                    raise TimeoutError("scheduler drain timed out")
        self._form_and_run()
        if (self.cfg.serve_idle_maintain and self.ex.maintenance_due()):
            self._maintain(idle=True)

    def close(self):
        """Stop accepting requests, flush the queue, join the worker."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=60.0)
            self._thread = None
        else:
            self._form_and_run()         # flush manual-mode leftovers

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- introspection ---------------------------------------------------

    def stats(self) -> dict:
        with self._cv:
            qlen, inflight = len(self._q), self._inflight
        return {
            "submitted": self.submitted,
            "queue_len": qlen,
            "inflight": inflight,
            "reads": self.reads,
            "read_batches": self.read_batches,
            "mean_batch": round(self.reads / max(self.read_batches, 1),
                                2),
            "max_batch": self.max_batch,
            "writes": self.writes,
            "write_merges": self.write_merges,
            "maintain_runs": self.maintain_runs,
            "maintain_busy": self.maintain_busy,
            "caps": dict(self.caps),
            "epoch": self.ex.epoch,
        }
