"""Serving layer: jitted prefill / decode steps + a batched session.

Mesh-aware: params shard FSDP x TP, caches per sharding.cache_specs
(batch / kv-head TP / sequence-parallel spill). The decode step is ONE
token for the whole batch — the unit the dry-run lowers and the roofline
scores (serve_step in the assignment's terms).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import MeshRules, cache_specs, param_specs, use_mesh


def make_prefill(model, *, mesh=None, rules: Optional[MeshRules] = None,
                 max_len: Optional[int] = None):
    rules = rules or MeshRules()

    def prefill(params, batch):
        with use_mesh(mesh, rules):
            return model.prefill(params, batch, max_len=max_len)

    return jax.jit(prefill)


def make_decode(model, *, mesh=None, rules: Optional[MeshRules] = None):
    rules = rules or MeshRules()

    def decode(params, cache, tokens, pos):
        with use_mesh(mesh, rules):
            return model.decode_step(params, cache, tokens, pos)

    return jax.jit(decode, donate_argnums=(1,))


def generate(model, params, batch, *, steps: int, mesh=None,
             rules: Optional[MeshRules] = None, max_len: Optional[int]
             = None, greedy: bool = True, rng=None):
    """Prefill + `steps` greedy/sampled tokens. Returns (B, steps)."""
    cfg = model.cfg
    prompt_len = batch["tokens"].shape[1] + (
        cfg.n_patches if getattr(cfg, "patch_input", False) and
        "patches" in batch else 0)
    max_len = max_len or (prompt_len + steps)
    prefill = make_prefill(model, mesh=mesh, rules=rules, max_len=max_len)
    decode = make_decode(model, mesh=mesh, rules=rules)
    logits, cache = prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(steps):
        toks.append(tok)
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
        if greedy or rng is None:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
                jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None
                                                             ].astype(
                jnp.int32)
    return jnp.concatenate(toks, axis=1)


class ServeSession:
    """Continuous batched serving: fixed-slot batch, per-slot positions.

    Simplified continuous batching: finished slots are refilled with new
    prompts via prefill-into-slot; the decode step always runs the full
    fixed batch (TPU-friendly static shapes).
    """

    def __init__(self, model, params, batch_size: int, max_len: int,
                 mesh=None, rules: Optional[MeshRules] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        if hasattr(model, "init_cache"):
            self.cache = model.init_cache(batch_size, max_len)
        else:
            self.cache = model.init_state(batch_size)
        if mesh is not None:
            self.cache = jax.device_put(
                self.cache, cache_specs(mesh, rules or MeshRules(),
                                        self.cache))
        self._decode = make_decode(model, mesh=mesh, rules=rules)

    def step(self, tokens):
        """tokens (B, 1) -> logits (B, 1, V); advances all slots."""
        # single shared scalar position (max), per-slot masking is the
        # batcher's concern; sufficient for throughput benchmarking
        pos = jnp.max(self.pos)
        logits, self.cache = self._decode(self.params, self.cache,
                                          tokens, pos)
        self.pos = self.pos + 1
        return logits
