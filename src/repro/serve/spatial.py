"""Spatial query serving: mixed QuerySpec workloads over one Executor.

The serving counterpart of serve/api.py's ServeSession, for the
paper's decision-analysis scenario: a long-lived process answering
heterogeneous spatial queries (point lookups, range analytics, kNN,
zone joins) against one resident learned index. Everything dispatches
through ``Executor.run`` (DESIGN.md §9), so:

  - steady-state requests with a sticky window hit run ONE fused
    executable with zero host syncs (no retry chain, no blocking
    bool(jnp.all(...)) reads on the hot path);
  - escalations triggered by an unusual request update the shared
    sticky tier once, and superseded compiled variants are evicted —
    the compiled-program footprint stays bounded over days of traffic;
  - ``warmup`` moves cold-start compilation + escalation off the
    serving path.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from jax.sharding import Mesh

from repro.core.build import LearnedSpatialIndex
from repro.core.executor import Executor
from repro.core.plan import (DeleteBatch, EngineConfig, InsertBatch,
                             QuerySpec)


class SpatialServeSession:
    """Serve mixed spatial query batches from a resident learned index."""

    def __init__(self, index: LearnedSpatialIndex,
                 mesh: Optional[Mesh] = None, part_axis: str = "data",
                 query_axis: Optional[str] = None,
                 config: Optional[EngineConfig] = None):
        # config defaults via a None sentinel: ``config=EngineConfig()``
        # in the signature would be evaluated ONCE at import and shared
        # by every session thereafter
        self.executor = Executor(index, mesh=mesh, part_axis=part_axis,
                                 query_axis=query_axis, config=config)

    def scheduler(self, bench=None, start: bool = True):
        """The streaming front door (serve/scheduler.py, DESIGN.md
        §12): a request queue + background worker coalescing concurrent
        submissions into micro-batches over THIS session's executor,
        with write barriers and idle-time maintain(). ``bench`` is a
        BENCH_quick.json path or dict for the per-spec batch caps
        (default: the committed file); ``start=False`` skips the worker
        thread — callers pump ``drain()`` (deterministic test mode)."""
        from repro.serve.scheduler import SpatialScheduler
        return SpatialScheduler(self.executor, bench=bench, start=start)

    def warmup(self, requests: Sequence[Tuple]) -> None:
        """Run representative requests before traffic arrives.

        The strict pass settles the sticky (cap, cand) tiers; the
        second, non-strict pass compiles the fused steady-path
        executables — so the first real request never blocks on XLA
        compilation.
        """
        self.executor.run_batch(requests, strict=True)
        self.executor.run_batch(requests)

    def submit(self, spec: QuerySpec, *args, strict: bool = False):
        """One request on the zero-sync steady path (strict=True forces
        the host-checked escalation loop, e.g. for a known-hard query)."""
        return self.executor.run(spec, *args, strict=strict)

    def submit_batch(self, requests: Sequence[Tuple],
                     strict: bool = False) -> list:
        """A mixed batch of (spec, *args) requests, in order."""
        return self.executor.run_batch(requests, strict=strict)

    # -- mutations (epoch-versioned mutable index, DESIGN.md §11) --------

    def insert(self, xs, ys):
        """Absorb a batch of new points into the resident index's delta
        buffers (no re-fit on this path; maintain() compacts when a
        partition's delta occupancy crosses the configured threshold).
        Returns the assigned point ids."""
        return self.executor.run(InsertBatch(), xs, ys)

    def delete(self, xs, ys) -> int:
        """Tombstone every live copy of each (x, y); returns the number
        of removed points. Queries remain exact immediately."""
        return self.executor.run(DeleteBatch(), xs, ys)

    def refit(self, touched=None):
        """Force compaction + per-partition spline re-fit now (e.g. in
        a maintenance window) instead of waiting for maintain()."""
        return self.executor.refit(touched)

    def maintain(self) -> dict:
        """Re-tune between batches: check the ok flags stashed by
        recent zero-sync runs, escalate any overflowed sticky tier, and
        run the deferred compaction+re-fit scheduled by updates whose
        delta occupancy crossed the threshold. Returns what moved.
        Call off the hot path."""
        return self.executor.maintain()

    def stats(self) -> dict:
        """Executor counters: host_syncs, dispatches, cache_size, sticky."""
        return self.executor.stats()
