from repro.serve.api import (  # noqa: F401
    make_prefill, make_decode, generate, ServeSession,
)
from repro.serve.spatial import SpatialServeSession  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    SpatialScheduler, Ticket, micro_batch_caps,
)
