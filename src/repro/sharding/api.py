"""Sharding rules: logical axes -> mesh axes, for params and activations.

Production mesh axes (launch/mesh.py):
  pod    outer data parallelism across pods (gradient sync crosses DCN)
  data   inner data parallelism + FSDP weight sharding + spatial partitions
  model  tensor parallelism (heads / ffn / experts / vocab)

Rules map LOGICAL axis names to mesh axes. Parameters get 2-D sharding
(FSDP over `data` x TP over `model`) so per-device state stays bounded at
1000+-node scale; a dimension is sharded only when divisible by the mesh
axis size (falls back to replication otherwise — e.g. kv_heads=2 on a
16-way model axis).

Activation constraints are applied through `constrain(x, *logical_axes)`,
a no-op unless a mesh context is active (`use_mesh`), so model code stays
pure and single-device tests never touch sharding machinery.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis mapping."""

    batch: Tuple[str, ...] = ("pod", "data")   # batch dim of activations
    fsdp: Tuple[str, ...] = ("data",)          # weight sharding (ZeRO-3)
    tp: Tuple[str, ...] = ("model",)           # tensor parallelism
    seq: Tuple[str, ...] = ("data",)           # sequence parallelism
    tp_seq: Tuple[str, ...] = ("model",)       # seq-parallel fallback for
    expert: Tuple[str, ...] = ("model",)       # indivisible head counts
    none: Tuple[str, ...] = ()

    def axes(self, name: Optional[str]):
        if name is None:
            return None
        got = getattr(self, name)
        return got if got else None


def _mesh_axes_present(mesh: Mesh, axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    return tuple(a for a in axes if a in mesh.shape)


def _axis_size(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def spec_for(mesh: Mesh, rules: MeshRules, shape, logical):
    """PartitionSpec for `shape` given per-dim logical names (or None).

    Drops shardings that don't divide the dimension size.
    """
    entries = []
    for dim, name in zip(shape, logical):
        axes = _mesh_axes_present(mesh, rules.axes(name))
        if axes and dim % _axis_size(mesh, axes) == 0:
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ---------------------------------------------------------------------------
# activation constraint context
# ---------------------------------------------------------------------------

def use_mesh(mesh: Optional[Mesh], rules: Optional[MeshRules] = None):
    """Context manager activating activation sharding constraints."""
    class _Ctx:
        def __enter__(self):
            _CTX.mesh = mesh
            _CTX.rules = rules or MeshRules()
            return self

        def __exit__(self, *exc):
            _CTX.mesh = None
            _CTX.rules = None
            return False

    return _Ctx()


def current_mesh() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def constrain(x, *logical):
    """with_sharding_constraint by logical axis names; no-op w/o context."""
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    rules = _CTX.rules
    spec = spec_for(mesh, rules, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_attn_acts(x, ref_heads=None, enable_seq_fallback: bool = True):
    """Sequence-TP fallback for (B, T, H, D) attention activations whose
    head count does NOT divide the model axis (gemma3: 8 q / 4 kv heads
    on 16-way TP). Without it, XLA shards head_dim across chips and
    every attention contraction becomes a score all-reduce (146 GB/chip
    measured on gemma prefill — EXPERIMENTS.md §Perf gemma iteration).

    Deliberately a NO-OP when heads divide TP: the first version
    constrained that case too and REGRESSED every head-divisible arch
    20-60% (SPMD propagation interference; §Perf optimized-sweep note) —
    the rule is "annotate only where propagation provably goes wrong".
    """
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None or x.ndim != 4 or not enable_seq_fallback:
        return x
    rules = _CTX.rules
    tp = _mesh_axes_present(mesh, rules.tp)
    tp_size = _axis_size(mesh, tp)
    b, t, h, d = x.shape
    # key the decision on the arch's QUERY head count so q/k/v stay
    # consistently sharded (dbrx: q=48 divisible but kv=8 not — mixing
    # head-TP q with seq-TP kv regressed tl 196 -> 805 s; measured)
    h_ref = ref_heads if ref_heads is not None else h
    # long sequences only: at train-scale seq (4k microbatches) the ring
    # exchange costs more than the head_dim split it avoids (gemma train
    # frac 0.034 -> 0.015 measured); at 32k prefill it wins 2.8-13x.
    if (not tp or h_ref % tp_size == 0 or t % tp_size != 0 or
            t < 8192):
        return x
    logical = ("batch", "tp_seq", None, None)
    spec = spec_for(mesh, rules, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


WEIGHT_GATHER = {"on": False}


def gather_weight(w, *logical):
    """Use-time weight re-shard (explicit ZeRO-3 gather). Tried as §Perf
    iteration 4 and REFUTED: constraining use-site copies to TP-only made
    XLA replicate the expert einsum across the data axis (compute term
    7.6 s -> 106 s on dbrx). Kept opt-in (WEIGHT_GATHER flag) for the
    record; default is a no-op — the productive fix was re-sharding the
    expert weights so the forward contraction dim is unsharded
    (iteration 5 in _param_logical)."""
    if not WEIGHT_GATHER["on"]:
        return w
    return constrain(w, *logical)


# ---------------------------------------------------------------------------
# parameter / batch / cache sharding trees
# ---------------------------------------------------------------------------

def _param_logical(path: str, shape) -> tuple:
    """Logical axes for a parameter from its tree path + rank.

    Conventions (see DESIGN.md §8): big matmul weights are FSDP x TP
    sharded; expert tensors put the expert dim on `expert` (=model);
    embeddings/heads shard the vocab on TP; vectors replicate.
    """
    nd = len(shape)
    leaf = path.split("/")[-1]
    if nd <= 1:
        return (None,) * nd
    if leaf in ("embed",):
        return ("tp", "fsdp")
    if leaf in ("lm_head",):
        return ("fsdp", "tp")
    if leaf in ("patch_proj", "frame_proj"):
        return (None, "fsdp")
    # expert weights: (expert->model) x (d->fsdp). §Perf iteration 5
    # tried flipping the fsdp dim to the non-contracted side and was
    # REFUTED (all-reduce 4.3 TB -> 14.7 TB: SPMD propagation re-derived
    # worse activation shardings downstream); this layout measured best.
    if leaf in ("we1", "we3"):               # (E, d, f)
        return ("expert", "fsdp", None)
    if leaf in ("we2",):                     # (E, f, d)
        return ("expert", None, "fsdp")
    if leaf in ("router",):
        return (None, None)
    if leaf in ("wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w1", "w3",
                "ws1", "ws3", "ck", "wr", "wg", "wx", "wd2"):
        return (None,) * (nd - 2) + ("fsdp", "tp")
    if leaf in ("wo", "w2", "ws2", "cv"):
        return (None,) * (nd - 2) + ("tp", "fsdp")
    if leaf in ("w_dq", "w_dkv", "wd1", "wb", "wc", "wdt"):
        return (None,) * (nd - 2) + ("fsdp", None)
    if leaf in ("wk_rwkv",):
        return (None,) * (nd - 2) + ("fsdp", "tp")
    return (None,) * nd

    # NOTE: scanned stacks have a leading layer dim handled by the caller.


def param_specs(mesh: Mesh, rules: MeshRules, params) -> dict:
    """Tree of NamedShardings matching the params tree."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    specs = {}
    out = []
    for kp, leaf in flat:
        ps = path_str(kp)
        shape = leaf.shape
        stacked = ("layers" in ps or "layer s" in ps or
                   "enc_layers" in ps or "dec_layers" in ps)
        core = shape[1:] if stacked and len(shape) > 1 else shape
        logical = _param_logical(ps, core)
        if stacked and len(shape) > 1:
            logical = (None,) + logical
        spec = spec_for(mesh, rules, shape, logical)
        specs[ps] = spec
        out.append(NamedSharding(mesh, spec))
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(mesh: Mesh, rules: MeshRules, batch) -> dict:
    """Batch arrays: dim 0 = batch -> (pod, data)."""
    def one(x):
        logical = ("batch",) + (None,) * (x.ndim - 1)
        return NamedSharding(mesh, spec_for(mesh, rules, x.shape, logical))

    return jax.tree_util.tree_map(one, batch)


def cache_specs(mesh: Mesh, rules: MeshRules, cache) -> dict:
    """Decode-cache sharding (greedy, per leaf).

    Leaves are (L, B, S, KV, D) / (L, B, S, r) [mla] / (L, B, H, N, P)
    [ssm] / (L, B, 1, d) [shift buffers]. Strategy:
      1. shard B over as much of (pod, data) as divides it;
      2. shard the heads dim (axis 3 of 5-D) over `model` when divisible
         (kv-head TP);
      3. spill remaining mesh axes onto the SEQUENCE dim (axis 2) —
         sequence parallelism; this is what makes B=1 / 500k-context
         caches fit a 16 GB chip, and what dbrx (kv=8 < model=16) needs.
    """
    batch_axes = _mesh_axes_present(mesh, rules.batch)
    tp_axes = _mesh_axes_present(mesh, rules.tp)

    def one(x):
        if x.ndim < 3:
            return NamedSharding(mesh, P())
        entries = [None] * x.ndim
        b = x.shape[1]
        used_batch = []
        prod = 1
        for a in batch_axes:
            if b % (prod * mesh.shape[a]) == 0:
                used_batch.append(a)
                prod *= mesh.shape[a]
        if used_batch:
            entries[1] = tuple(used_batch) if len(used_batch) > 1 else \
                used_batch[0]
        leftover = [a for a in batch_axes if a not in used_batch]
        # heads TP (5-D KV caches)
        tp_used = False
        if x.ndim >= 5:
            heads = x.shape[3]
            sz = _axis_size(mesh, tp_axes)
            if tp_axes and heads % sz == 0:
                entries[3] = tuple(tp_axes) if len(tp_axes) > 1 else \
                    tp_axes[0]
                tp_used = True
        # spill onto sequence dim
        seq_axes = list(leftover) + ([] if tp_used else list(tp_axes))
        seq_axes = [a for a in seq_axes
                    if x.shape[2] % mesh.shape[a] == 0 and
                    x.shape[2] >= mesh.shape[a]]
        # keep divisibility for the combined product
        picked = []
        for a in seq_axes:
            prod = int(np.prod([mesh.shape[u] for u in picked] or [1]))
            if x.shape[2] % (prod * mesh.shape[a]) == 0:
                picked.append(a)
        if picked:
            entries[2] = tuple(picked) if len(picked) > 1 else picked[0]
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(one, cache)
