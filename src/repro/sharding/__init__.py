from repro.sharding.api import (  # noqa: F401
    constrain, gather_weight, shard_attn_acts, use_mesh, param_specs,
    batch_specs, cache_specs, MeshRules, current_mesh,
)
