"""Quickstart: build a LiLIS learned spatial index and query it through
the declarative plan/executor API.

A query is described by a frozen QuerySpec (WHAT to compute) and
executed by the Executor (HOW: compilation, candidate-window tuning,
distribution). Adding a query type means adding a spec + one local
kernel — see src/repro/core/plan.py and DESIGN.md §9.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CircleQuery, Executor, Knn, PointQuery,
                        RangeCount, RangeQuery, SpatialJoin, build_index,
                        fit)
from repro.data import spatial as ds


def main():
    # 1. a synthetic "city" of 200k points
    x, y = ds.make("taxi", 200_000, seed=0)

    # 2. spatial-aware partitioning (paper §3.1; KD-tree is the default)
    part = fit("kdtree", x, y, num_partitions=64)

    # 3. one-pass learned index build (paper §3.2)
    index = build_index(x, y, part)
    sizes = index.size_bytes()
    print(f"index: {index.num_partitions} partitions, "
          f"model {sizes['local_model']/1e3:.0f} KB for "
          f"{len(x)*12/1e6:.0f} MB of points")

    # 4. one executor serves every query type (pass mesh=... to shard)
    ex = Executor(index)

    # point query (paper §4.1)
    found = ex.run(PointQuery(), x[:4], y[:4])
    print("point query (known points):", np.asarray(found))

    # range count + materializing range query (paper §4.2)
    rects = ds.random_rects(8, 1e-4, part.bounds, seed=1, centers=(x, y))
    print("range counts:", np.asarray(ex.run(RangeCount(), rects)))
    cnt, vids, ok = ex.run(RangeQuery(), rects)
    print("range ids[0][:5]:", np.asarray(vids)[0][:5])

    # circle query with distance refine (paper Remark 2)
    r = np.full(4, 0.02, np.float32)
    print("circle counts:",
          np.asarray(ex.run(CircleQuery(), x[:4], y[:4], r)))

    # kNN (paper §4.3)
    d2, ids = ex.run(Knn(k=5), x[:4], y[:4])
    print("knn ids[0]:", np.asarray(ids)[0])

    # spatial join (paper §4.4)
    polys, n_edges = ds.random_polygons(4, part.bounds, seed=2)
    print("join counts:",
          np.asarray(ex.run(SpatialJoin(), polys, n_edges)))

    # mixed workloads dispatch through one entry point; once the
    # adaptive window tiers are sticky, re-runs are zero-host-sync
    batch = ex.run_batch([(RangeCount(), rects), (Knn(k=5), x[:4], y[:4])])
    print("batched:", np.asarray(batch[0])[:4], "...,",
          np.asarray(batch[1][1])[0][:3])
    print("executor stats:", ex.stats())


if __name__ == "__main__":
    main()
