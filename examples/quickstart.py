"""Quickstart: build a LiLIS learned spatial index and query it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds


def main():
    # 1. a synthetic "city" of 200k points
    x, y = ds.make("taxi", 200_000, seed=0)

    # 2. spatial-aware partitioning (paper §3.1; KD-tree is the default)
    part = fit("kdtree", x, y, num_partitions=64)

    # 3. one-pass learned index build (paper §3.2)
    index = build_index(x, y, part)
    sizes = index.size_bytes()
    print(f"index: {index.num_partitions} partitions, "
          f"model {sizes['local_model']/1e3:.0f} KB for "
          f"{len(x)*12/1e6:.0f} MB of points")

    engine = SpatialEngine(index)

    # point query (paper §4.1)
    found = engine.point_query(x[:4], y[:4])
    print("point query (known points):", np.asarray(found))

    # range query (paper §4.2)
    rects = ds.random_rects(8, 1e-4, part.bounds, seed=1, centers=(x, y))
    counts = engine.range_count(rects)
    print("range counts:", np.asarray(counts))

    # kNN (paper §4.3)
    d2, ids = engine.knn(x[:4], y[:4], k=5)
    print("knn ids[0]:", np.asarray(ids)[0])

    # spatial join (paper §4.4)
    polys, n_edges = ds.random_polygons(4, part.bounds, seed=2)
    print("join counts:", np.asarray(engine.join_count(polys, n_edges)))


if __name__ == "__main__":
    main()
