"""End-to-end spatial decision analysis (the paper's use case):
"which shops fall within each commercial zone?" — a polygon x points
broadcast join + density ranking, served from the learned index, plus a
distributed variant when multiple devices are available.

    PYTHONPATH=src python examples/spatial_analytics.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spatial_analytics.py --dist
"""
import argparse
import time

import jax
import numpy as np

from repro.core import Executor, Knn, SpatialJoin, build_index, fit
from repro.data import spatial as ds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500_000)
    ap.add_argument("--zones", type=int, default=32)
    ap.add_argument("--dist", action="store_true",
                    help="shard partitions over all local devices")
    args = ap.parse_args()

    print(f"{args.n} shops, {args.zones} commercial zones")
    x, y = ds.make("taxi", args.n, seed=7)          # shop locations
    part = fit("kdtree", x, y, 64, seed=0)
    index = build_index(x, y, part)

    mesh = None
    if args.dist:
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("data",))
        print(f"distributed over {n_dev} devices")
    executor = Executor(index, mesh=mesh)

    zones, n_edges = ds.random_polygons(args.zones, part.bounds, seed=3,
                                        radius=0.05)
    t0 = time.perf_counter()
    counts = np.asarray(executor.run(SpatialJoin(), zones, n_edges))
    dt = time.perf_counter() - t0
    order = np.argsort(-counts)
    print(f"join of {args.zones} zones x {args.n} shops: {dt*1e3:.0f} ms")
    print("densest zones (zone id, shop count):")
    for i in order[:5]:
        print(f"  zone {i:3d}: {counts[i]:6d} shops")

    # follow-up: 10 nearest shops to each of the top zone centroids
    cent = np.stack([zones[order[:5], :, 0].mean(axis=1),
                     zones[order[:5], :, 1].mean(axis=1)], axis=1)
    d2, ids = executor.run(Knn(k=10), cent[:, 0].astype(np.float32),
                           cent[:, 1].astype(np.float32))
    print("nearest shops to densest zone:", np.asarray(ids)[0][:5])


if __name__ == "__main__":
    main()
