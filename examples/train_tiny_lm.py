"""Train a ~100M-param qwen2.5-family model for a few hundred steps on
whatever devices exist, with checkpoint/auto-resume — the end-to-end
training driver at example scale.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.train import TrainLoopConfig, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
    ap.add_argument("--dim", type=int, default=512,
                    help="512 -> ~100M params with the qwen vocab")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2.5-3b", smoke=True),
        vocab=32768, d_model=args.dim, n_layers=8,
        n_heads=8, n_kv_heads=2, head_dim=args.dim // 8,
        d_ff=args.dim * 4, max_seq=1024)
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params")

    step = make_train_step(model, peak_lr=3e-4, warmup=20,
                           total_steps=args.steps, n_micro=1)
    pipe = TokenPipeline(cfg, batch=8, seq=256, seed=0)
    loop = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt,
                           ckpt_every=100, log_every=20)
    params, opt, hist = train_loop(model, step, pipe, loop,
                                   rng=jax.random.PRNGKey(0))
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"({len(hist['loss'])} steps run this session)")


if __name__ == "__main__":
    main()
