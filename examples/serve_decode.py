"""Batched serving example: prefill + greedy decode with KV caches,
across three cache disciplines (GQA / MLA-compressed / RWKV state).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_config
from repro.data.tokens import make_batch
from repro.models import build_model
from repro.serve import generate


def run(arch: str, steps: int = 24):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32, seed=1)
    b = {"tokens": batch["tokens"]}
    if "patches" in batch:
        b["patches"] = batch["patches"]
    t0 = time.perf_counter()
    out = generate(model, params, b, steps=steps)
    dt = time.perf_counter() - t0
    kind = {"transformer": "GQA/MLA cache", "rwkv6": "O(1) state",
            "hymba": "window cache + SSM state"}.get(cfg.family,
                                                     cfg.family)
    print(f"{arch:24s} [{kind:22s}] {out.shape[0] * out.shape[1] / dt:7.1f}"
          f" tok/s  first tokens: {out[0, :6].tolist()}")


def main():
    for arch in ["qwen2.5-3b", "deepseek-v2-lite-16b", "rwkv6-3b",
                 "hymba-1.5b"]:
        run(arch)


if __name__ == "__main__":
    main()
