"""RQ2 (paper Table 3): LiLIS-{F,A,Q,K,R} partitioner sweep."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_N, BENCH_Q, emit, timeit
from repro.core import STRATEGIES, SpatialEngine, build_index, fit
from repro.data import spatial as ds

TAGS = {"fixed": "F", "adaptive": "A", "quadtree": "Q", "kdtree": "K",
        "rtree": "R"}


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    rng = np.random.default_rng(1)
    ix = rng.integers(0, BENCH_N, BENCH_Q)
    qx, qy = x[ix], y[ix]
    q = BENCH_Q

    for kind in STRATEGIES:
        part = fit(kind, x, y, 64, seed=0)
        eng = SpatialEngine(build_index(x, y, part))
        rects = ds.random_rects(BENCH_Q, 1e-5, part.bounds, seed=2,
                                centers=(x, y))
        polys, ne = ds.random_polygons(8, part.bounds, seed=3)
        tag = TAGS[kind]
        emit(f"rq2/point/LiLIS-{tag}",
             timeit(lambda: eng.point_query(qx, qy)) / q)
        emit(f"rq2/range/LiLIS-{tag}",
             timeit(lambda: eng.range_query(rects)[0]) / q)
        emit(f"rq2/knn/LiLIS-{tag}",
             timeit(lambda: eng.knn(qx, qy, 10)[0]) / q)
        emit(f"rq2/join/LiLIS-{tag}",
             timeit(lambda: eng.join_count(polys, ne)) / 8)


if __name__ == "__main__":
    main()
