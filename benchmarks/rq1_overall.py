"""RQ1 (paper Fig. 4): overall performance, LiLIS vs baselines.

Four query types under default settings (selectivity 1e-5 skewed rects,
k=10) against fullscan (~Spark), binsearch (sort-only), gridonly
(~Sedona-N two-phase) — all on the same JAX substrate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_N, BENCH_Q, BinSearchEngine,
                               FullScanEngine, GridOnlyEngine, emit,
                               timeit)
from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)
    index = build_index(x, y, part)
    lilis = SpatialEngine(index)
    grid = GridOnlyEngine(index)
    full = FullScanEngine(x, y)
    bins = BinSearchEngine(x, y, index.key_spec)

    rng = np.random.default_rng(1)
    ix = rng.integers(0, BENCH_N, BENCH_Q)
    qx, qy = x[ix], y[ix]
    rects = ds.random_rects(BENCH_Q, 1e-5, part.bounds, seed=2,
                            centers=(x, y))
    polys, ne = ds.random_polygons(16, part.bounds, seed=3)

    q = BENCH_Q
    emit("rq1/point/lilis", timeit(lambda: lilis.point_query(qx, qy)) / q)
    emit("rq1/point/gridonly", timeit(lambda: grid.point_query(qx, qy))
         / q)
    emit("rq1/point/fullscan", timeit(lambda: full.point_query(qx, qy))
         / q)

    emit("rq1/range/lilis",
         timeit(lambda: lilis.range_query(rects)[0]) / q)
    emit("rq1/range/gridonly",
         timeit(lambda: grid.range_count(rects)) / q)
    emit("rq1/range/binsearch",
         timeit(lambda: bins.range_count(rects)) / q)
    emit("rq1/range/fullscan",
         timeit(lambda: full.range_count(rects)) / q)

    k = 10
    emit("rq1/knn/lilis",
         timeit(lambda: lilis.knn(qx, qy, k, mode="pruned")[0]) / q)
    emit("rq1/knn/gridonly",
         timeit(lambda: grid.knn(qx, qy, k, mode="exact")[0]) / q)
    emit("rq1/knn/fullscan", timeit(lambda: full.knn(qx, qy, k)[0]) / q)

    emit("rq1/join/lilis",
         timeit(lambda: lilis.join_count(polys, ne)) / 16)
    emit("rq1/join/fullscan",
         timeit(lambda: full.join_count(polys, ne)) / 16)

    # scaling row: the learned-index gap grows with N (paper's regime is
    # billions of rows on a cluster; 1M on one core shows the trend)
    n2 = 1_000_000
    x2, y2 = ds.make("taxi", n2, seed=0)
    part2 = fit("kdtree", x2, y2, 256, seed=0)
    eng2 = SpatialEngine(build_index(x2, y2, part2))
    full2 = FullScanEngine(x2, y2)
    ix2 = rng.integers(0, n2, BENCH_Q)
    qx2, qy2 = x2[ix2], y2[ix2]
    rects2 = ds.random_rects(BENCH_Q, 1e-5, part2.bounds, seed=2,
                             centers=(x2, y2))
    emit("rq1/range@1M/lilis",
         timeit(lambda: eng2.range_query(rects2)[0]) / q)
    emit("rq1/range@1M/fullscan",
         timeit(lambda: full2.range_count(rects2)) / q)
    emit("rq1/knn@1M/lilis",
         timeit(lambda: eng2.knn(qx2, qy2, 10)[0]) / q)
    emit("rq1/knn@1M/fullscan",
         timeit(lambda: full2.knn(qx2, qy2, 10)[0]) / q)
    emit("rq1/point@1M/lilis",
         timeit(lambda: eng2.point_query(qx2, qy2)) / q)
    emit("rq1/point@1M/fullscan",
         timeit(lambda: full2.point_query(qx2, qy2)) / q)


if __name__ == "__main__":
    main()
