"""RQ1 (paper Fig. 4): overall performance, LiLIS vs baselines.

Four query types under default settings (selectivity 1e-5 skewed rects,
k=10) against fullscan (~Spark), binsearch (sort-only), gridonly
(~Sedona-N two-phase) — all on the same JAX substrate, all driven by
the SAME QuerySpec plan objects so the comparison is apples-to-apples
at the API level too.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_N, BENCH_Q, BinSearchEngine,
                               FullScanEngine, GridOnlyEngine, emit,
                               lilis_config, timeit)
from repro.core import (Executor, Knn, PointQuery, RangeCount,
                        RangeQuery, SpatialJoin, build_index, fit)
from repro.data import spatial as ds


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)
    index = build_index(x, y, part)
    lilis = Executor(index, config=lilis_config())
    grid = GridOnlyEngine(index)
    full = FullScanEngine(x, y)
    bins = BinSearchEngine(x, y, index.key_spec)

    rng = np.random.default_rng(1)
    ix = rng.integers(0, BENCH_N, BENCH_Q)
    qx, qy = x[ix], y[ix]
    rects = ds.random_rects(BENCH_Q, 1e-5, part.bounds, seed=2,
                            centers=(x, y))
    polys, ne = ds.random_polygons(16, part.bounds, seed=3)

    q = BENCH_Q
    point = PointQuery()
    emit("rq1/point/lilis", timeit(lambda: lilis.run(point, qx, qy)) / q)
    emit("rq1/point/gridonly", timeit(lambda: grid.run(point, qx, qy))
         / q)
    emit("rq1/point/fullscan", timeit(lambda: full.run(point, qx, qy))
         / q)

    rq = RangeQuery()
    rc = RangeCount()
    emit("rq1/range/lilis",
         timeit(lambda: lilis.run(rq, rects)[0]) / q)
    emit("rq1/range/gridonly",
         timeit(lambda: grid.run(rc, rects)) / q)
    emit("rq1/range/binsearch",
         timeit(lambda: bins.run(rc, rects)) / q)
    emit("rq1/range/fullscan",
         timeit(lambda: full.run(rc, rects)) / q)

    knn = Knn(k=10)
    emit("rq1/knn/lilis",
         timeit(lambda: lilis.run(knn, qx, qy)[0]) / q)
    emit("rq1/knn/gridonly",
         timeit(lambda: grid.run(Knn(k=10, mode="exact"), qx, qy)[0])
         / q)
    emit("rq1/knn/fullscan", timeit(lambda: full.run(knn, qx, qy)[0]) / q)

    join = SpatialJoin()
    emit("rq1/join/lilis",
         timeit(lambda: lilis.run(join, polys, ne)) / 16)
    emit("rq1/join/fullscan",
         timeit(lambda: full.run(join, polys, ne)) / 16)

    # scaling row: the learned-index gap grows with N (paper's regime is
    # billions of rows on a cluster; 1M on one core shows the trend)
    n2 = 1_000_000
    x2, y2 = ds.make("taxi", n2, seed=0)
    part2 = fit("kdtree", x2, y2, 256, seed=0)
    ex2 = Executor(build_index(x2, y2, part2), config=lilis_config())
    full2 = FullScanEngine(x2, y2)
    ix2 = rng.integers(0, n2, BENCH_Q)
    qx2, qy2 = x2[ix2], y2[ix2]
    rects2 = ds.random_rects(BENCH_Q, 1e-5, part2.bounds, seed=2,
                             centers=(x2, y2))
    emit("rq1/range@1M/lilis",
         timeit(lambda: ex2.run(rq, rects2)[0]) / q)
    emit("rq1/range@1M/fullscan",
         timeit(lambda: full2.run(rc, rects2)) / q)
    emit("rq1/knn@1M/lilis",
         timeit(lambda: ex2.run(knn, qx2, qy2)[0]) / q)
    emit("rq1/knn@1M/fullscan",
         timeit(lambda: full2.run(knn, qx2, qy2)[0]) / q)
    emit("rq1/point@1M/lilis",
         timeit(lambda: ex2.run(point, qx2, qy2)) / q)
    emit("rq1/point@1M/fullscan",
         timeit(lambda: full2.run(point, qx2, qy2)) / q)


if __name__ == "__main__":
    main()
