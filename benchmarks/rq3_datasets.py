"""RQ3 (paper Fig. 5 + Table 4): dataset sweep.

uniform ~ SYN, gaussian ~ CHI, taxi ~ NYC. Table-4 comparison: LiLIS-K
vs the full-scan baseline for kNN on every dataset.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_N, BENCH_Q, FullScanEngine, emit,
                               timeit)
from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds


def main():
    for gen in ["uniform", "gaussian", "taxi"]:
        x, y = ds.make(gen, BENCH_N, seed=0)
        part = fit("kdtree", x, y, 64, seed=0)
        eng = SpatialEngine(build_index(x, y, part))
        full = FullScanEngine(x, y)
        rng = np.random.default_rng(1)
        ix = rng.integers(0, BENCH_N, BENCH_Q)
        qx, qy = x[ix], y[ix]
        rects = ds.random_rects(BENCH_Q, 1e-5, part.bounds, seed=2,
                                centers=(x, y))
        q = BENCH_Q
        emit(f"rq3/point/{gen}",
             timeit(lambda: eng.point_query(qx, qy)) / q)
        emit(f"rq3/range/{gen}",
             timeit(lambda: eng.range_query(rects)[0]) / q)
        emit(f"rq3/knn/{gen}", timeit(lambda: eng.knn(qx, qy, 10)[0]) / q)
        emit(f"rq3/knn-fullscan/{gen}",
             timeit(lambda: full.knn(qx, qy, 10)[0]) / q)


if __name__ == "__main__":
    main()
