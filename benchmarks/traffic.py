"""Mixed read/write traffic benchmark: the streaming serve scheduler.

The millions-of-users traffic shape (ROADMAP open item 1, now closed):
many small concurrent point/range/circle/kNN requests plus a live
ingest stream of inserts/deletes, served through the scheduler front
door (serve/scheduler.py, DESIGN.md §12). Two phases per backend:

  throughput  the SAME request sequence through serial ``submit()``
              (call-and-wait, one dispatch per request) and through
              the scheduler's deterministic drain (coalesced
              micro-batches) — queries/s both ways, results compared
              BITWISE per request. The acceptance bar: coalesced
              throughput >= serial throughput, zero result drift.
  mixed       closed-loop client threads issuing single-query reads
              against a live worker-thread scheduler while an ingest
              thread streams InsertBatch/DeleteBatch through the same
              queue — p50/p99 request latency, queries/s, ingest
              ops/s, and the off-hot-path maintenance observation
              (``maintain_busy`` must stay 0: maintain() only ever ran
              with an empty queue).

``bench_serve(...)`` returns the dict the quick bench commits as the
``serve`` column of BENCH_quick.json; ``tools/check.sh`` gates p50/qps
under the standard 25% regression table (SKIP_BENCH_DIFF honored) and
hard-asserts the deterministic invariants (bitwise parity, idle-only
maintenance).
"""
from __future__ import annotations

import json
import threading
import time

import jax
import numpy as np

from benchmarks.common import BENCH_N, emit
from repro.core import (CircleQuery, DeleteBatch, EngineConfig,
                        InsertBatch, Knn, PointQuery, RangeCount,
                        RangeQuery, build_index, fit)
from repro.data import spatial as ds
from repro.serve import SpatialServeSession

READ_REQS = 192          # phase-1 requests (mixed widths 1..3)
MIXED_READS = 128        # phase-2 closed-loop single-query reads
CLIENTS = 4
INGEST_BATCH = 64
INGEST_ROUNDS = 6


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(la, lb))


def _traffic(x, y, part, n_req, seed, widths=(1, 2, 3)):
    """A mixed request sequence: small batches over 5 read specs."""
    rng = np.random.default_rng(seed)
    rects = ds.random_rects(n_req * 3, 1e-4, part.bounds,
                            seed=seed + 1, centers=(x, y))
    reqs = []
    for i in range(n_req):
        w = widths[i % len(widths)]
        ix = rng.integers(0, len(x), w)
        qx, qy = x[ix], y[ix]
        kind = i % 5
        if kind == 0:
            reqs.append((PointQuery(), qx, qy))
        elif kind == 1:
            reqs.append((RangeCount(), rects[3 * i:3 * i + w]))
        elif kind == 2:
            reqs.append((RangeQuery(), rects[3 * i:3 * i + w]))
        elif kind == 3:
            reqs.append((CircleQuery(), qx, qy,
                         np.full(w, 0.02, np.float32)))
        else:
            reqs.append((Knn(k=10), qx, qy))
    return reqs


def bench_serve(index, x, y, part, backend: str) -> dict:
    # delta capacity covers the whole ingest stream so the mixed phase
    # measures steady dispatch, not a mid-run buffer-growth recompile
    cfg = EngineConfig(backend=backend,
                       delta_cap=2 * INGEST_ROUNDS * INGEST_BATCH)
    session = SpatialServeSession(index, config=cfg)
    warm = _traffic(x, y, part, 10, seed=90)
    session.warmup(warm)

    # ---- phase 1: serial vs coalesced throughput, bitwise parity ----
    reqs = _traffic(x, y, part, READ_REQS, seed=91)
    n_queries = sum(r[1].shape[0] for r in reqs)
    # settle width-specific executables for BOTH modes off the clock:
    # serial compiles per arrival width, the scheduler per power-of-two
    # bucket — one untimed pass each over an identically-shaped warmup
    # traffic leaves only steady-state dispatch on the clock
    warm2 = _traffic(x, y, part, READ_REQS, seed=92)
    for spec, *args in warm2:
        session.submit(spec, *args)
    sched = session.scheduler(start=False)
    for spec, *args in warm2:
        sched.submit(spec, *args)
    sched.drain()

    t0 = time.perf_counter()
    serial = [session.submit(spec, *args) for spec, *args in reqs]
    jax.block_until_ready(serial)
    dt_serial = time.perf_counter() - t0
    serial_qps = n_queries / dt_serial

    tickets = [sched.submit(spec, *args) for spec, *args in reqs]
    t0 = time.perf_counter()
    sched.drain()
    dt_sched = time.perf_counter() - t0
    qps = n_queries / dt_sched
    bitwise = all(_tree_equal(t.result(), ref)
                  for t, ref in zip(tickets, serial))
    st1 = sched.stats()
    sched.close()

    # ---- phase 2: concurrent clients + ingest stream (worker mode) --
    lat_us = []
    lat_lock = threading.Lock()
    ingest_ops = 0
    with session.scheduler(start=True) as live:
        rng = np.random.default_rng(93)
        bx = np.repeat(x, 2)[:INGEST_ROUNDS * INGEST_BATCH] \
            + rng.normal(0, 1e-4, INGEST_ROUNDS * INGEST_BATCH)
        by = np.repeat(y, 2)[:INGEST_ROUNDS * INGEST_BATCH] \
            + rng.normal(0, 1e-4, INGEST_ROUNDS * INGEST_BATCH)
        bx, by = bx.astype(np.float32), by.astype(np.float32)
        # prewarm the update executables (batch-width keyed)
        live.submit(InsertBatch(), bx[:INGEST_BATCH],
                    by[:INGEST_BATCH]).result(120.0)
        live.submit(DeleteBatch(), bx[:8], by[:8]).result(120.0)

        reads = _traffic(x, y, part, MIXED_READS, seed=94, widths=(1,))
        # untimed concurrent warm pass: the timed phase's reads arrive
        # concurrently and coalesce into power-of-two buckets the
        # serial/drain warmups never shaped — compile those off the
        # clock so p99 measures dispatch, not first-bucket compiles
        def _warm_client(k, rs):
            for i in range(k, len(rs), CLIENTS):
                spec, *args = rs[i]
                live.submit(spec, *args).result(120.0)
        for rs in (reads, reads):
            ws = [threading.Thread(target=_warm_client, args=(k, rs))
                  for k in range(CLIENTS)]
            for w in ws:
                w.start()
            for w in ws:
                w.join()
        done = threading.Event()

        def ingest():
            nonlocal ingest_ops
            i = 1
            while not done.is_set() and i < INGEST_ROUNDS:
                lo = i * INGEST_BATCH
                tw = live.submit(InsertBatch(), bx[lo:lo + INGEST_BATCH],
                                 by[lo:lo + INGEST_BATCH])
                tw.result(120.0)
                ingest_ops += INGEST_BATCH
                td = live.submit(DeleteBatch(), bx[lo:lo + 8],
                                 by[lo:lo + 8])
                td.result(120.0)
                ingest_ops += 8
                i += 1

        def client(k):
            mine = []
            for i in range(k, len(reads), CLIENTS):
                spec, *args = reads[i]
                t0 = time.perf_counter()
                live.submit(spec, *args).result(120.0)
                mine.append((time.perf_counter() - t0) * 1e6)
            with lat_lock:
                lat_us.extend(mine)

        t0 = time.perf_counter()
        ing = threading.Thread(target=ingest)
        cls = [threading.Thread(target=client, args=(k,))
               for k in range(CLIENTS)]
        ing.start()
        for c in cls:
            c.start()
        for c in cls:
            c.join()
        done.set()
        ing.join()
        wall = time.perf_counter() - t0
        live.drain()
        # idle now: give the worker one beat to run deferred maintain()
        for _ in range(200):
            if live.stats()["maintain_runs"] > 0:
                break
            time.sleep(0.005)
        st2 = live.stats()

    out = {
        "reads": READ_REQS,
        "queries": int(n_queries),
        "serial_qps": round(serial_qps, 1),
        "qps": round(qps, 1),
        "coalesce_speedup": round(qps / max(serial_qps, 1e-9), 2),
        "bitwise_vs_serial": bool(bitwise),
        "mean_batch": st1["mean_batch"],
        "max_batch": st1["max_batch"],
        "clients": CLIENTS,
        "p50_us": round(float(np.percentile(lat_us, 50)), 1),
        "p99_us": round(float(np.percentile(lat_us, 99)), 1),
        "mixed_read_qps": round(len(lat_us) / wall, 1),
        "ingest_ops_per_s": round(ingest_ops / wall, 1),
        "maintain_runs": st2["maintain_runs"],
        "maintain_busy": st2["maintain_busy"],
        "write_merges": st2["write_merges"],
    }
    emit(f"traffic/{backend}/serial_qps", 1e6 / max(serial_qps, 1e-9))
    emit(f"traffic/{backend}/sched_qps", 1e6 / max(qps, 1e-9))
    emit(f"traffic/{backend}/p50_us", out["p50_us"])
    emit(f"traffic/{backend}/p99_us", out["p99_us"])
    return out


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, min(16, BENCH_N // 256 or 1), seed=0)
    index = build_index(x, y, part)
    jax.block_until_ready(index.key)
    report = {}
    for backend in ("xla", "pallas"):
        report[backend] = bench_serve(index, x, y, part, backend)
    print(json.dumps(report, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
