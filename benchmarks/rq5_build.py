"""RQ5 (paper Fig. 8): index construction cost.

Compares, over identical pre-partitioned data:
  * LiLIS local learned index: per-partition spline + radix fit
    (the paper's O(N) one-pass after the sort),
  * STR R-tree local index packing (the Sedona-style comparator,
    O(N log N + N log f * log_f N)),
  * a sort-only lower bound,
plus the end-to-end build (assign + shuffle + fit).

Both comparators run single-threaded on the same CPU (the paper's
cluster comparison collapses to per-core build throughput here).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BENCH_N, emit, timeit
from repro.core import build_index, fit
from repro.core import keys as K
from repro.core.build import fit_partitions
from repro.data import spatial as ds


def str_pack(xs, ys, fanout=64):
    """STR R-tree packing (numpy, bottom-up leaf + internal levels)."""
    n = len(xs)
    order = np.argsort(xs, kind="stable")
    xs, ys = xs[order], ys[order]
    s = int(np.ceil(np.sqrt(n / fanout)))
    per = int(np.ceil(n / s))
    boxes = []
    for i in range(0, n, per):
        cx, cy = xs[i:i + per], ys[i:i + per]
        o2 = np.argsort(cy, kind="stable")
        cx, cy = cx[o2], cy[o2]
        for j in range(0, len(cx), fanout):
            tx, ty = cx[j:j + fanout], cy[j:j + fanout]
            boxes.append((tx.min(), ty.min(), tx.max(), ty.max()))
    boxes = np.asarray(boxes, np.float32)
    # internal levels
    while len(boxes) > 1:
        nxt = []
        for j in range(0, len(boxes), fanout):
            b = boxes[j:j + fanout]
            nxt.append((b[:, 0].min(), b[:, 1].min(), b[:, 2].max(),
                        b[:, 3].max()))
        boxes = np.asarray(nxt, np.float32)
    return boxes


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)

    # end-to-end distributed build (assign + sort/shuffle + learn)
    emit("rq5/build/lilis-end2end",
         timeit(lambda: build_index(x, y, part).key, repeat=3))

    # isolate the LOCAL index fit on identical layouted data
    idx = build_index(x, y, part)
    key_g, counts = idx.key, idx.count
    m_pad = idx.knot_keys.shape[1]
    emit("rq5/build/lilis-local-fit",
         timeit(lambda: fit_partitions(
             key_g, counts, eps=idx.eps, m_pad=m_pad,
             radix_bits=idx.radix_bits)["n_knots"], repeat=3))

    # STR R-tree packing over the same points (per partition)
    xs_np = np.asarray(idx.x)
    ys_np = np.asarray(idx.y)
    cnts = np.asarray(counts)

    def build_str():
        for p in range(idx.num_partitions):
            c = cnts[p]
            if c:
                str_pack(xs_np[p, :c], ys_np[p, :c])

    t0 = time.perf_counter()
    build_str()
    emit("rq5/build/rtree-str-local", (time.perf_counter() - t0) * 1e6)

    # sort-only lower bound
    keys = K.make_keys(jax.numpy.asarray(x), jax.numpy.asarray(y),
                       idx.key_spec)
    emit("rq5/build/sort-only",
         timeit(lambda: jax.numpy.sort(keys), repeat=3))

    sizes = idx.size_bytes()
    emit("rq5/size/local-model-bytes", sizes["local_model"],
         f"data={BENCH_N * 12}")
    emit("rq5/size/global-index-bytes", sizes["global_index"])


if __name__ == "__main__":
    main()
