"""Measured crossover for ``EngineConfig.query_shard_threshold``.

``python -m benchmarks.run --crossover`` times the SAME RangeCount
workload through an unsharded executor and a query-axis-sharded one
(threshold forced to 1) at a few batch widths, prints the per-batch
us/q table, and records the recommended threshold — the smallest
measured batch where the sharded path wins, or above the sweep if it
never does — into BENCH_quick.json (``crossover`` key, preserved by
--quick reruns), closing the ROADMAP's "pick the threshold from
measured crossover" item.

run.py forces a multi-device host platform (XLA_FLAGS) before jax
initializes; on a machine whose devices are fake host threads the
sharded path typically loses at every width — a real measurement too:
it says "keep batches unsharded here", i.e. a threshold above the
largest production batch.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BATCHES = (64, 256, 1024, 4096)
OUT = os.environ.get("BENCH_QUICK_OUT", "BENCH_quick.json")


def _steady(ex, spec, args, repeat: int = 3) -> float:
    import jax
    jax.block_until_ready(ex.run(spec, *args))
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(ex.run(spec, *args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / args[0].shape[0]


def main():
    import jax

    from benchmarks.common import BENCH_N, emit
    from repro.core import (EngineConfig, Executor, RangeCount,
                            build_index, fit)
    from repro.data import spatial as ds

    ndev = jax.device_count()
    if ndev < 2:
        raise SystemExit("--crossover needs >= 2 devices (run.py sets "
                         "XLA_FLAGS for the host platform)")
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, min(16, BENCH_N // 256 or 1), seed=0)
    index = build_index(x, y, part)

    mesh = jax.make_mesh((1, ndev), ("data", "query"))
    plain = Executor(index, config=EngineConfig())
    sharded = Executor(index, mesh=mesh, part_axis="data",
                       query_axis="query",
                       config=EngineConfig(query_shard_threshold=1))

    spec = RangeCount()
    rows = {}
    wins = {}
    for q in BATCHES:
        rects = ds.random_rects(q, 1e-4, part.bounds, seed=q,
                                centers=(x, y))
        tu = _steady(plain, spec, (rects,))
        ts = _steady(sharded, spec, (rects,))
        rows[q] = {"unsharded_us_per_q": round(tu, 2),
                   "sharded_us_per_q": round(ts, 2)}
        emit(f"crossover/q{q}/unsharded", tu)
        emit(f"crossover/q{q}/sharded", ts)
        wins[q] = ts < tu
    # the pick must be noise-robust: recommend the smallest width where
    # the sharded path wins there AND at every larger swept width (one
    # lucky small-batch timing must not shard all production traffic)
    crossed = None
    for q in sorted(BATCHES, reverse=True):
        if not wins[q]:
            break
        crossed = q
    # never crossed -> recommend a threshold above the sweep (keep
    # batches unsharded on this substrate)
    recommended = crossed if crossed is not None else 2 * max(BATCHES)
    print(f"# crossover: sharded wins from q={crossed} "
          f"-> recommended query_shard_threshold={recommended}"
          if crossed is not None else
          f"# crossover: sharded never won up to q={max(BATCHES)} "
          f"-> recommended query_shard_threshold={recommended}")

    record = {"devices": ndev, "batches": rows,
              "recommended_query_shard_threshold": recommended}
    report = {}
    if os.path.exists(OUT):
        try:
            with open(OUT) as f:
                report = json.load(f)
        except (OSError, ValueError):
            report = {}
    report["crossover"] = record
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote crossover record to {OUT}")


if __name__ == "__main__":
    main()
