"""Benchmark suite entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only rq1,...]``
Emits ``name,us_per_call,derived`` CSV lines.

``PYTHONPATH=src python -m benchmarks.run --quick``
Smoke mode: tiny BENCH_N/BENCH_Q, every QuerySpec through the unified
executor, writes BENCH_quick.json (see tools/check.sh).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = ["rq1_overall", "rq2_partitioners", "rq3_datasets",
           "rq4_selectivity", "rq4_knn_k", "rq5_build", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: tiny sizes, all QuerySpecs, "
                         "emit BENCH_quick.json")
    ap.add_argument("--traffic", action="store_true",
                    help="mixed read/write traffic through the serve "
                         "scheduler only (coalesced vs serial qps, "
                         "p50/p99 latency, ingest ops/s)")
    ap.add_argument("--crossover", action="store_true",
                    help="measure the query_shard_threshold crossover "
                         "(sharded vs unsharded) and record the pick "
                         "in BENCH_quick.json")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "xla", "pallas"],
                    help="kernel backend for the lilis engines "
                         "(--quick always benchmarks every backend)")
    args = ap.parse_args()
    if args.backend:
        # must be set before benchmarks.common is imported
        os.environ["BENCH_BACKEND"] = args.backend
    picked = MODULES
    if args.quick:
        # must be set before benchmarks.common is imported
        os.environ.setdefault("BENCH_N", "20000")
        os.environ.setdefault("BENCH_Q", "16")
        os.environ.setdefault("BENCH_REPEAT", "1")
        picked = ["quick"]
    elif args.traffic:
        os.environ.setdefault("BENCH_N", "20000")
        picked = ["traffic"]
    elif args.crossover:
        # multi-device host platform BEFORE jax initializes
        os.environ.setdefault("BENCH_N", "20000")
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=2"
            ).strip()
        picked = ["crossover"]
    elif args.only:
        pre = args.only.split(",")
        picked = [m for m in MODULES if any(m.startswith(p) for p in pre)]
    print("name,us_per_call,derived")
    failures = 0
    for name in picked:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
