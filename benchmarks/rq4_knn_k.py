"""RQ4b (paper Fig. 7): kNN k-sweep across LiLIS partitioner variants."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_N, emit, timeit
from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds

TAGS = {"fixed": "F", "quadtree": "Q", "kdtree": "K", "rtree": "R"}


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    rng = np.random.default_rng(1)
    nq = 32
    ix = rng.integers(0, BENCH_N, nq)
    qx, qy = x[ix], y[ix]
    engines = {}
    for kind, tag in TAGS.items():
        part = fit(kind, x, y, 64, seed=0)
        engines[tag] = SpatialEngine(build_index(x, y, part))
    for k in [1, 10, 50, 100]:
        for tag, eng in engines.items():
            emit(f"rq4/knn-k/LiLIS-{tag}/k={k}",
                 timeit(lambda: eng.knn(qx, qy, k)[0]) / nq)


if __name__ == "__main__":
    main()
