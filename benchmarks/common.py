"""Shared benchmark utilities: timing, baselines, CSV emission.

Baselines (all on the SAME JAX substrate so the comparison isolates the
index, not the framework):

  fullscan   no index at all — brute force over every point (the
             paper's "Spark" baseline).
  binsearch  one sorted array + searchsorted, no partitioner, no spline
             (classic sort-based index; isolates the partitioning win).
  gridonly   spatial partitioning + per-partition FULL scan refine, no
             learned interval (the paper's "Sedona-N"-like two-phase
             baseline; isolates the learned-index win).
  lilis      partitioner + learned spline/radix windowed paths.

Every baseline speaks the declarative QuerySpec plan API via
``run(spec, *args)`` — the exact entry point the lilis Executor
serves — so timings compare the same query descriptions end to end.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import (CircleQuery, Knn, PointQuery, RangeCount,
                             RangeQuery, SpatialJoin)

BENCH_N = int(os.environ.get("BENCH_N", 200_000))
BENCH_Q = int(os.environ.get("BENCH_Q", 64))
REPEAT = int(os.environ.get("BENCH_REPEAT", 3))
# kernel backend for the lilis engines (run.py --backend sets this;
# baselines are backend-independent by construction)
BENCH_BACKEND = os.environ.get("BENCH_BACKEND", "auto")


def lilis_config():
    """EngineConfig for benchmark lilis engines (honors --backend)."""
    from repro.core.plan import EngineConfig
    return EngineConfig(backend=BENCH_BACKEND)

_rows = []


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    _rows.append((name, us_per_call, derived))


def timeit(fn, repeat: int = REPEAT):
    fn()  # compile / warm (cold path: strict attempt chain)
    fn()  # second warm compiles the executor's fused steady variant
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


# ---------------------------------------------------------------------------
# baseline engines
# ---------------------------------------------------------------------------

class FullScanEngine:
    """No index: brute force over all points (vectorized, jitted)."""

    def __init__(self, x, y):
        self.x = jnp.asarray(x)
        self.y = jnp.asarray(y)

        @jax.jit
        def _range(rects):
            m = ((self.x[None, :] >= rects[:, 0:1]) &
                 (self.x[None, :] <= rects[:, 2:3]) &
                 (self.y[None, :] >= rects[:, 1:2]) &
                 (self.y[None, :] <= rects[:, 3:4]))
            return jnp.sum(m.astype(jnp.int32), axis=1)

        @jax.jit
        def _point(qx, qy):
            return jnp.any((self.x[None, :] == qx[:, None]) &
                           (self.y[None, :] == qy[:, None]), axis=1)

        def _knn(qx, qy, k):
            @jax.jit
            def go(qx, qy):
                d2 = ((self.x[None, :] - qx[:, None]) ** 2 +
                      (self.y[None, :] - qy[:, None]) ** 2)
                return jax.lax.top_k(-d2, k)
            return go(qx, qy)

        @jax.jit
        def _join(polys, n_edges):
            from repro.core.queries import point_in_polygon

            def one(poly, ne):
                return jnp.sum(point_in_polygon(
                    self.x, self.y, poly, ne).astype(jnp.int32))

            return jax.lax.map(lambda a: one(*a), (polys, n_edges))

        @jax.jit
        def _circle(cx, cy, r):
            d2 = ((self.x[None, :] - cx[:, None]) ** 2 +
                  (self.y[None, :] - cy[:, None]) ** 2)
            return jnp.sum((d2 <= (r * r)[:, None]).astype(jnp.int32),
                           axis=1)

        self.range_count = _range
        self.point_query = _point
        self.knn = _knn
        self.join_count = _join
        self.circle_count = _circle

    def run(self, spec, *args):
        """QuerySpec dispatch — same plan vocabulary as the Executor.

        Materializing specs are rejected rather than silently answered
        with a bare count array (the return shapes would not match the
        Executor contract and would skew symmetric comparisons).
        """
        if isinstance(spec, PointQuery):
            return self.point_query(*args)
        if isinstance(spec, RangeCount):
            return self.range_count(*args)
        if isinstance(spec, CircleQuery) and not spec.materialize:
            return self.circle_count(*args)
        if isinstance(spec, Knn):
            return self.knn(*args, spec.k)
        if isinstance(spec, SpatialJoin):
            return self.join_count(*args)
        raise TypeError(f"fullscan baseline: unsupported {spec!r} "
                        "(counts only — use RangeCount/CircleQuery)")


class BinSearchEngine:
    """Sorted keys + searchsorted: no partitioning, no learned model."""

    def __init__(self, x, y, spec):
        from repro.core import keys as K
        keys = K.make_keys(jnp.asarray(x), jnp.asarray(y), spec)
        order = jnp.argsort(keys)
        self.keys_f = K.keys_to_f32(keys[order])
        self.x = jnp.asarray(x)[order]
        self.y = jnp.asarray(y)[order]
        self.spec = spec

        @jax.jit
        def _range(rects, klo, khi):
            s = jnp.searchsorted(self.keys_f, klo)
            e = jnp.searchsorted(self.keys_f, khi + 1.0)
            pos = jnp.arange(self.keys_f.shape[0])
            m = ((pos[None, :] >= s[:, None]) &
                 (pos[None, :] < e[:, None]) &
                 (self.x[None, :] >= rects[:, 0:1]) &
                 (self.x[None, :] <= rects[:, 2:3]) &
                 (self.y[None, :] >= rects[:, 1:2]) &
                 (self.y[None, :] <= rects[:, 3:4]))
            return jnp.sum(m.astype(jnp.int32), axis=1)

        self._range = _range

    def range_count(self, rects):
        from repro.core import keys as K
        klo, khi = K.rect_key_range(jnp.asarray(rects), self.spec)
        return self._range(jnp.asarray(rects), K.keys_to_f32(klo),
                           K.keys_to_f32(khi))

    def run(self, spec, *args):
        """QuerySpec dispatch (sort-only baseline: range counts only)."""
        if isinstance(spec, RangeCount):
            return self.range_count(*args)
        raise TypeError(f"binsearch baseline: unsupported {spec!r}")


class GridOnlyEngine:
    """Partition pruning + full per-partition refine (no spline)."""

    def __init__(self, index):
        import dataclasses
        from repro.core.engine import SpatialEngine
        # learned bounds replaced by the full row: emulate by setting the
        # radix/spline to predict [0, count) always — probe the engine
        # with an index whose learned interval is the whole partition.
        idx2 = dataclasses.replace(
            index,
            knot_keys=jnp.stack(
                [jnp.full((index.num_partitions,), -1.0, jnp.float32),
                 jnp.full((index.num_partitions,), 3e38, jnp.float32)],
            axis=1),
            knot_pos=jnp.stack(
                [jnp.zeros((index.num_partitions,), jnp.float32),
                 index.count.astype(jnp.float32)], axis=1),
            n_knots=jnp.full((index.num_partitions,), 2, jnp.int32),
            radix_table=jnp.zeros_like(index.radix_table),
            radix_kmin=jnp.full((index.num_partitions,), -1.0,
                                jnp.float32),
            radix_scale=jnp.zeros((index.num_partitions,), jnp.float32),
            probe=index.n_pad,
        )
        self.eng = SpatialEngine(idx2)

    def run(self, spec, *args):
        """QuerySpec dispatch through the degenerate-interval engine."""
        return self.eng.run(spec, *args, strict=True)

    def __getattr__(self, name):
        return getattr(self.eng, name)
