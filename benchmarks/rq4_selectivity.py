"""RQ4a (paper Fig. 6): range-query selectivity x skewness sweep."""
from __future__ import annotations

from benchmarks.common import BENCH_N, BENCH_Q, emit, timeit
from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    part = fit("kdtree", x, y, 64, seed=0)
    eng = SpatialEngine(build_index(x, y, part))
    q = BENCH_Q
    for sel in [1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3]:
        skewed = ds.random_rects(q, sel, part.bounds, seed=3,
                                 centers=(x, y))
        uniform = ds.random_rects(q, sel, part.bounds, seed=3)
        emit(f"rq4/range-skewed/sel={sel:g}",
             timeit(lambda: eng.range_query(skewed)[0]) / q)
        emit(f"rq4/range-uniform/sel={sel:g}",
             timeit(lambda: eng.range_query(uniform)[0]) / q)


if __name__ == "__main__":
    main()
