"""Quick smoke benchmark: every QuerySpec through the unified executor.

Runs in seconds on tiny BENCH_N/BENCH_Q (set by ``run.py --quick``),
timing each spec cold (compile + sticky settle) and steady (fused
zero-sync path) on EVERY kernel backend (xla reference + pallas, the
latter in interpret mode off-TPU), and writes ``BENCH_quick.json`` —
the perf-trajectory artifact a CI check diffs across PRs
(tools/check.sh fails on a >25% steady-state regression of the default
backend vs the committed file).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import BENCH_N, BENCH_Q, emit
from repro.core import (CircleQuery, DeleteBatch, EngineConfig, Executor,
                        InsertBatch, Knn, PointQuery, RangeCount,
                        RangeQuery, SpatialJoin, build_index, fit,
                        resolve_backend)
from repro.data import spatial as ds

OUT = os.environ.get("BENCH_QUICK_OUT", "BENCH_quick.json")


def bench_backend(index, backend: str, workload, workload256) -> dict:
    ex = Executor(index, config=EngineConfig(backend=backend))
    specs = {}
    for name, spec, args, denom in workload:
        t0 = time.perf_counter()
        jax.block_until_ready(ex.run(spec, *args))
        cold = (time.perf_counter() - t0) * 1e6 / denom
        syncs0 = ex.host_syncs
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(ex.run(spec, *args))
            best = min(best, time.perf_counter() - t0)
        steady = best * 1e6 / denom
        specs[name] = {
            "cold_us_per_q": round(cold, 2),
            "steady_us_per_q": round(steady, 2),
            "steady_host_syncs": ex.host_syncs - syncs0,
        }
        emit(f"quick/{backend}/{name}/steady", steady)
    # q=256 batch column: compaction gains scale with batch width. The
    # SAME executor serves it — sticky tiers are already settled, so the
    # wide batch costs one shape-specialized compile of the warm fused
    # program and then times the zero-sync steady path.
    for name, spec, args, denom in workload256:
        jax.block_until_ready(ex.run(spec, *args))      # shape compile
        best = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            jax.block_until_ready(ex.run(spec, *args))
            best = min(best, time.perf_counter() - t0)
        steady = best * 1e6 / denom
        specs[name]["steady_us_per_q_b256"] = round(steady, 2)
        emit(f"quick/{backend}/{name}/steady_b256", steady)
    executor = {k: v for k, v in ex.stats().items() if k != "sticky"}
    executor["sticky"] = {
        str(k): list(v) for k, v in ex.stats()["sticky"].items()}
    return {"specs": specs, "executor": executor}


def bench_updates(index, x, y, backend: str, workload) -> dict:
    """Update-throughput column (DESIGN.md §11): batched inserts/s into
    the delta buffers, the compaction+re-fit cost, and the post-update
    steady us/q of the range + circle specs — the regression gate pins
    that absorbing updates does not tax steady serving. Shares main()'s
    built index: mutations replace executor state functionally and
    never touch the original pytree."""
    ub = 256
    ex = Executor(index, config=EngineConfig(backend=backend,
                                             delta_cap=4 * ub))
    qspecs = {name: (spec, args, denom) for name, spec, args, denom
              in workload if name in ("range", "circle")}
    for spec, args, _ in qspecs.values():     # settle sticky + fused
        jax.block_until_ready(ex.run(spec, *args, strict=True))
        jax.block_until_ready(ex.run(spec, *args))

    rng = np.random.default_rng(7)
    bx = np.repeat(x, 2)[: 3 * ub] + rng.normal(0, 1e-4, 3 * ub)
    by = np.repeat(y, 2)[: 3 * ub] + rng.normal(0, 1e-4, 3 * ub)
    bx = bx.astype(np.float32)
    by = by.astype(np.float32)
    ex.run(InsertBatch(), bx[:ub], by[:ub])   # compile + grow once
    best = float("inf")
    for i in (1, 2):
        t0 = time.perf_counter()
        ex.run(InsertBatch(), bx[i * ub:(i + 1) * ub],
               by[i * ub:(i + 1) * ub])
        best = min(best, time.perf_counter() - t0)
    insert_us = best * 1e6 / ub
    ex.run(DeleteBatch(), bx[:32], by[:32])

    t0 = time.perf_counter()
    touched = ex.refit()
    jax.block_until_ready(ex.index.key)   # time completion, not dispatch
    refit_ms = (time.perf_counter() - t0) * 1e3

    out = {"insert_batch": ub,
           "insert_us_per_op": round(insert_us, 2),
           "inserts_per_s": round(1e6 / max(insert_us, 1e-9)),
           "refit_partitions": len(touched),
           "refit_ms": round(refit_ms, 2)}
    for name, (spec, args, denom) in qspecs.items():
        jax.block_until_ready(ex.run(spec, *args))    # recompile settle
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(ex.run(spec, *args))
            best = min(best, time.perf_counter() - t0)
        steady = best * 1e6 / denom
        out[f"post_{name}_us_per_q"] = round(steady, 2)
        emit(f"quick/{backend}/upd_{name}/steady", steady)
    emit(f"quick/{backend}/insert/us_per_op", insert_us)
    return out


def main():
    x, y = ds.make("taxi", BENCH_N, seed=0)
    t0 = time.perf_counter()
    part = fit("kdtree", x, y, min(16, BENCH_N // 256 or 1), seed=0)
    index = build_index(x, y, part)
    jax.block_until_ready(index.key)
    build_ms = (time.perf_counter() - t0) * 1e3

    rng = np.random.default_rng(1)
    q = BENCH_Q
    ix = rng.integers(0, BENCH_N, q)
    qx, qy = x[ix], y[ix]
    rects = ds.random_rects(q, 1e-4, part.bounds, seed=2, centers=(x, y))
    polys, ne = ds.random_polygons(max(q // 8, 4), part.bounds, seed=3)
    r = np.full(q, 0.02, np.float32)

    workload = [
        ("point", PointQuery(), (qx, qy), q),
        ("range_count", RangeCount(), (rects,), q),
        ("range", RangeQuery(), (rects,), q),
        ("circle", CircleQuery(), (qx, qy, r), q),
        ("circle_mat", CircleQuery(materialize=True), (qx, qy, r), q),
        ("knn10", Knn(k=10), (qx, qy), q),
        ("knn10_exact", Knn(k=10, mode="exact"), (qx, qy), q),
        ("join", SpatialJoin(), (polys, ne), len(ne)),
    ]

    # wide-batch column (q=256): per-point specs only — the exact-scan
    # and join specs would dominate wall-clock without adding compaction
    # signal (their work is already ~linear in the batch)
    q2 = 256
    ix2 = rng.integers(0, BENCH_N, q2)
    qx2, qy2 = x[ix2], y[ix2]
    rects2 = ds.random_rects(q2, 1e-4, part.bounds, seed=4,
                             centers=(x, y))
    r2 = np.full(q2, 0.02, np.float32)
    workload256 = [
        ("point", PointQuery(), (qx2, qy2), q2),
        ("range_count", RangeCount(), (rects2,), q2),
        ("range", RangeQuery(), (rects2,), q2),
        ("circle", CircleQuery(), (qx2, qy2, r2), q2),
        ("circle_mat", CircleQuery(materialize=True), (qx2, qy2, r2),
         q2),
        ("knn10", Knn(k=10), (qx2, qy2), q2),
    ]

    default = resolve_backend("auto").name
    order = [default] + [b for b in ("xla", "pallas") if b != default]
    report = {"bench_n": BENCH_N, "bench_q": q, "bench_q_wide": q2,
              "build_ms": build_ms,
              "backend_default": default, "backends": {}}
    from benchmarks.traffic import bench_serve
    for backend in order:
        out = bench_backend(index, backend, workload, workload256)
        out["updates"] = bench_updates(index, x, y, backend, workload)
        # serve column: scheduler-coalesced vs serial throughput, mixed
        # read/write latency, idle-only maintenance (benchmarks/traffic.py)
        out["serve"] = bench_serve(index, x, y, part, backend)
        report["backends"][backend] = out
    # back-compat view: the default backend is the serving configuration
    # whose trajectory the CI regression gate tracks
    report["specs"] = report["backends"][default]["specs"]
    report["executor"] = report["backends"][default]["executor"]
    # keep the measured query_shard_threshold record (written by
    # ``run.py --crossover``) stable across --quick reruns
    if os.path.exists(OUT):
        try:
            with open(OUT) as f:
                prev = json.load(f)
            if "crossover" in prev:
                report["crossover"] = prev["crossover"]
        except (OSError, ValueError):
            pass
    with open(OUT, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT}")


if __name__ == "__main__":
    main()
