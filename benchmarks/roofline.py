"""Roofline report: aggregates results/dryrun/*.json into the §Roofline
table (one row per arch x shape x mesh) — markdown + CSV."""
from __future__ import annotations

import glob
import json
import os

OUT_MD = "results/roofline.md"
OUT_CSV = "results/roofline.csv"


def load_all(pattern=None):
    sources = ([pattern] if pattern else
               ["results/dryrun/*.json",
                "results/dryrun_opt/*.json",
                "results/dryrun_spatial/*.json"])
    rows = []
    for pat in sources:
        variant = ("optimized" if "opt" in pat else
                   "spatial" if "spatial" in pat else "baseline")
        for path in sorted(glob.glob(pat)):
            with open(path) as f:
                rep = json.load(f)
            r = rep["roofline"]
            rows.append({
                "variant": variant,
                "arch": rep["arch"], "shape": rep["shape"],
                "mesh": rep["mesh"], "chips": rep["chips"],
                "tc": r["t_compute_s"], "tm": r["t_memory_s"],
                "tl": r["t_collective_s"],
                "bottleneck": r["bottleneck"],
                "useful": r["useful_flops_frac"],
                "roofline_frac": r["roofline_frac"],
                "params": rep.get("params", 0),
                "active": rep.get("active_params", 0),
                "flops_per_chip": r["flops"],
                "hbm_per_chip": r["hbm_bytes"],
                "link_per_chip": r["link_bytes"],
            })
    return rows


def main():
    rows = load_all()
    if not rows:
        print("roofline,0,no dryrun results found")
        return
    os.makedirs("results", exist_ok=True)
    hdr = ("| variant | arch | shape | mesh | t_comp (s) | t_mem (s) "
           "| t_coll (s) | bound | useful | roofline |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    csv = ["variant,arch,shape,mesh,chips,t_compute_s,t_memory_s,"
           "t_collective_s,bottleneck,useful_flops_frac,roofline_frac"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"], r["variant"])):
        lines.append(
            f"| {r['variant']} | {r['arch']} | {r['shape']} "
            f"| {r['mesh']} "
            f"| {r['tc']:.2e} | {r['tm']:.2e} | {r['tl']:.2e} "
            f"| {r['bottleneck']} | {r['useful']:.2f} "
            f"| {r['roofline_frac']:.3f} |")
        csv.append(
            f"{r['variant']},{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['chips']},"
            f"{r['tc']:.4e},{r['tm']:.4e},{r['tl']:.4e},"
            f"{r['bottleneck']},{r['useful']:.3f},"
            f"{r['roofline_frac']:.4f}")
    with open(OUT_MD, "w") as f:
        f.write("\n".join(lines) + "\n")
    with open(OUT_CSV, "w") as f:
        f.write("\n".join(csv) + "\n")
    # run.py-compatible summary rows
    worst = min(rows, key=lambda r: r["roofline_frac"])
    print(f"roofline/cells,{len(rows)},table at {OUT_MD}")
    print(f"roofline/worst,{worst['roofline_frac']:.4f},"
          f"{worst['arch']}/{worst['shape']}/{worst['mesh']}")
    colls = [r for r in rows if r["bottleneck"] == "collective"]
    print(f"roofline/collective-bound,{len(colls)},of {len(rows)} cells")


if __name__ == "__main__":
    main()
