import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.api import (MeshRules, cache_specs, param_specs,
                                spec_for)


def fake_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """An abstract mesh over repeated devices (spec logic only)."""
    devs = np.asarray(jax.devices() * int(np.prod(shape)))[
        : int(np.prod(shape))].reshape(shape)
    return Mesh(devs, axes)


def test_spec_for_divisibility():
    mesh = fake_mesh()
    rules = MeshRules()
    # divisible dims shard; indivisible fall back to replication
    s = spec_for(mesh, rules, (8, 6), ("fsdp", "tp"))
    assert s == P("data", "model")
    s = spec_for(mesh, rules, (7, 6), ("fsdp", "tp"))
    assert s == P(None, "model")
    s = spec_for(mesh, rules, (8, 4096), ("batch", None))
    assert s == P(("pod", "data"))


def test_param_specs_rules():
    mesh = fake_mesh()
    rules = MeshRules()
    import jax.numpy as jnp
    params = {
        "embed": jnp.zeros((64, 32)),
        "layers": {"attn": {"wq": jnp.zeros((4, 32, 64))},
                   "moe": {"we1": jnp.zeros((4, 8, 32, 64))}},
    }
    specs = param_specs(mesh, rules, params)
    assert specs["embed"].spec == P("model", "data")
    # stacked (L, d, H*hd): layer dim replicated, fsdp x tp on the rest
    assert specs["layers"]["attn"]["wq"].spec == P(None, "data", "model")
    # experts on model, d on fsdp (trailing None trimmed)
    assert specs["layers"]["moe"]["we1"].spec == P(None, "model", "data")


def test_cache_specs_decode_32k_kv_indivisible():
    """dbrx-style: kv=8 < model=16 -> heads replicate, SEQ takes model."""
    mesh = fake_mesh((2, 4), ("data", "model"))
    rules = MeshRules()
    import jax.numpy as jnp
    cache = {"k": jnp.zeros((4, 8, 64, 2, 16))}  # (L,B,S,KV=2? ->
    specs = cache_specs(mesh, rules, cache)
    sp = specs["k"].spec
    assert sp[1] == "data"          # batch 8 % 2 == 0
    # kv=2 not divisible by model=4 -> seq picks up model
    assert sp[2] == "model" and (len(sp) < 4 or sp[3] is None)


def test_cache_specs_b1_seq_spill():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = MeshRules()
    import jax.numpy as jnp
    cache = {"k": jnp.zeros((2, 1, 64, 4, 8))}   # B=1, kv=4 % 2 == 0
    sp = cache_specs(mesh, rules, cache)["k"].spec
    assert sp[1] is None                         # B=1 unshardable
    assert sp[2] == ("pod", "data")              # seq spill
    assert sp[3] == "model"                      # kv TP


def test_constrain_noop_without_context():
    import jax.numpy as jnp
    from repro.sharding import constrain
    x = jnp.zeros((4, 4))
    assert constrain(x, "batch", None) is x
