import numpy as np
import pytest

from repro.core import STRATEGIES, fit
from repro.core.build import assign_partitions
import jax.numpy as jnp


@pytest.mark.parametrize("kind", list(STRATEGIES))
@pytest.mark.parametrize("gen", ["uniform", "gaussian", "taxi"])
def test_every_point_gets_a_partition(kind, gen):
    from repro.data import spatial as ds
    x, y = ds.make(gen, 5000, seed=3)
    part = fit(kind, x, y, 16, seed=1)
    pid = np.asarray(assign_partitions(
        jnp.asarray(x), jnp.asarray(y),
        jnp.asarray(part.partition_bounds()[:-1])))
    assert pid.min() >= 0
    assert pid.max() <= part.num_grids  # overflow id == num_grids
    # tiling partitioners should rarely overflow; rtree may (paper §3.1)
    frac_overflow = np.mean(pid == part.num_grids)
    if kind in ("fixed", "adaptive", "kdtree", "quadtree"):
        assert frac_overflow < 0.01
    assert len(pid) == len(x)


def test_rtree_overflow_grid_exists():
    """Bottom-up STR leaves bound only the sample -> some points overflow
    (the paper's novel overflow-grid concept)."""
    from repro.data import spatial as ds
    x, y = ds.make("uniform", 20000, seed=5)
    part = fit("rtree", x, y, 16, sample_rate=0.005, seed=2)
    pid = np.asarray(assign_partitions(
        jnp.asarray(x), jnp.asarray(y),
        jnp.asarray(part.partition_bounds()[:-1])))
    assert (pid == part.num_grids).sum() > 0


@pytest.mark.parametrize("kind", list(STRATEGIES))
def test_boxes_are_valid(kind):
    from repro.data import spatial as ds
    x, y = ds.make("gaussian", 4000, seed=9)
    part = fit(kind, x, y, 9, seed=1)
    b = part.boxes
    assert (b[:, 0] <= b[:, 2]).all() and (b[:, 1] <= b[:, 3]).all()
    assert part.num_partitions == part.num_grids + 1


def test_balance_kdtree_better_than_fixed_on_skew():
    """Spatial-aware partitioning is the paper's load-balance mechanism."""
    from repro.data import spatial as ds
    x, y = ds.make("gaussian", 30000, seed=11)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def imbalance(kind):
        part = fit(kind, x, y, 16, seed=1)
        pid = np.asarray(assign_partitions(
            xj, yj, jnp.asarray(part.partition_bounds()[:-1])))
        counts = np.bincount(pid, minlength=part.num_partitions)
        return counts.max() / max(counts.mean(), 1)

    assert imbalance("kdtree") < imbalance("fixed")
