"""Chunked linear-attention scan vs naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.linear_attn import chunked_linear_attn, \
    linear_attn_decode


def naive_rwkv(q, k, v, logw, bonus):
    """o_t = q_t . (S_t + diag(u) k_t v_t^T); S_{t+1} = diag(w_t) S_t +
    k_t v_t^T (f64 reference)."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    S = np.zeros((b, h, dk, dv))
    out = np.zeros((b, t, h, dv))
    w = np.exp(np.asarray(logw, np.float64))
    q, k, v = (np.asarray(a, np.float64) for a in (q, k, v))
    u = np.asarray(bonus, np.float64) if bonus is not None else None
    for i in range(t):
        kv = np.einsum("bhd,bhv->bhdv", k[:, i], v[:, i])
        # bonus term adds the u-weighted CURRENT token; without bonus the
        # current token is excluded (strict causality), matching the
        # chunked form (SSD callers fold the current token themselves).
        eff = S + u[None, :, :, None] * kv if u is not None else S
        out[:, i] = np.einsum("bhd,bhdv->bhv", q[:, i], eff)
        S = w[:, i][..., None] * S + kv
    return out, S


@pytest.mark.parametrize("t,chunk", [(8, 4), (64, 16), (96, 32)])
def test_chunked_matches_naive_rwkv(t, chunk):
    rng = np.random.default_rng(t)
    b, h, dk, dv = 2, 3, 8, 8
    q = jnp.asarray(rng.standard_normal((b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, dv)), jnp.float32)
    logw = jnp.asarray(-np.exp(rng.standard_normal((b, t, h, dk)) * 0.5),
                       jnp.float32)
    bonus = jnp.asarray(rng.standard_normal((h, dk)), jnp.float32)
    out, st = chunked_linear_attn(q, k, v, logw, chunk=chunk, bonus=bonus)
    want, wst = naive_rwkv(q, k, v, logw, bonus)
    assert np.allclose(np.asarray(out, np.float64), want, atol=2e-3)
    assert np.allclose(np.asarray(st), wst, atol=2e-3)


def test_decode_consistent_with_chunked():
    rng = np.random.default_rng(0)
    b, t, h, dk, dv = 1, 12, 2, 4, 4
    q = jnp.asarray(rng.standard_normal((b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, dv)), jnp.float32)
    logw = jnp.asarray(-np.exp(rng.standard_normal((b, t, h, dk)) * 0.3),
                       jnp.float32)
    bonus = jnp.asarray(rng.standard_normal((h, dk)), jnp.float32)
    out_c, st_c = chunked_linear_attn(q, k, v, logw, chunk=4, bonus=bonus)
    st = jnp.zeros((b, h, dk, dv), jnp.float32)
    outs = []
    for i in range(t):
        o, st = linear_attn_decode(q[:, i], k[:, i], v[:, i],
                                   logw[:, i], st, bonus=bonus)
        outs.append(o)
    out_d = jnp.stack(outs, axis=1)
    assert np.allclose(np.asarray(out_c), np.asarray(out_d), atol=2e-3)
    assert np.allclose(np.asarray(st_c), np.asarray(st), atol=2e-3)


def test_state_threading_across_calls():
    """prefill(first half) + prefill(second half w/ state) == full."""
    rng = np.random.default_rng(1)
    b, t, h, dk, dv = 2, 32, 2, 4, 4
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = mk(b, t, h, dk), mk(b, t, h, dk), mk(b, t, h, dv)
    logw = jnp.asarray(-np.exp(rng.standard_normal((b, t, h, dk)) * 0.3),
                       jnp.float32)
    full, st_full = chunked_linear_attn(q, k, v, logw, chunk=8)
    h1, st1 = chunked_linear_attn(q[:, :16], k[:, :16], v[:, :16],
                                  logw[:, :16], chunk=8)
    h2, st2 = chunked_linear_attn(q[:, 16:], k[:, 16:], v[:, 16:],
                                  logw[:, 16:], chunk=8, state=st1)
    assert np.allclose(np.asarray(full[:, 16:]), np.asarray(h2),
                       atol=2e-3)
    assert np.allclose(np.asarray(st_full), np.asarray(st2), atol=2e-3)
