import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.moe import moe_ffn


def _params(rng, d, e, f, shared=0):
    k = jax.random.split(rng, 7)
    p = {
        "router": jax.random.normal(k[0], (d, e)) * 0.1,
        "we1": jax.random.normal(k[1], (e, d, f)) * 0.1,
        "we3": jax.random.normal(k[2], (e, d, f)) * 0.1,
        "we2": jax.random.normal(k[3], (e, f, d)) * 0.1,
    }
    if shared:
        p.update({"ws1": jax.random.normal(k[4], (d, f * shared)) * 0.1,
                  "ws3": jax.random.normal(k[5], (d, f * shared)) * 0.1,
                  "ws2": jax.random.normal(k[6], (f * shared, d)) * 0.1})
    return p


def test_single_expert_topk1_equals_dense():
    """E=1, top_k=1, high capacity => MoE == plain swiglu FFN."""
    from repro.models.common import swiglu
    cfg = ModelConfig(d_model=16, n_experts=1, top_k=1, d_expert=32,
                      moe=True, capacity_factor=4.0)
    p = _params(jax.random.PRNGKey(0), 16, 1, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out, aux = moe_ffn(p, x, cfg)
    want = swiglu(x.reshape(-1, 16), p["we1"][0], p["we3"][0],
                  p["we2"][0]).reshape(2, 8, 16)
    assert np.allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_capacity_drops_tokens():
    cfg = ModelConfig(d_model=8, n_experts=4, top_k=1, d_expert=16,
                      moe=True, capacity_factor=0.1)
    p = _params(jax.random.PRNGKey(2), 8, 4, 16)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 8))
    out, _ = moe_ffn(p, x, cfg)
    # with tiny capacity most tokens get zero output
    norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (norms < 1e-6).sum() > 20


def test_aux_loss_uniformity():
    """Balanced routing -> aux ~ 1; collapsed routing -> aux > 1."""
    cfg = ModelConfig(d_model=8, n_experts=4, top_k=2, d_expert=16,
                      moe=True)
    p = _params(jax.random.PRNGKey(4), 8, 4, 16)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 128, 8))
    _, aux = moe_ffn(p, x, cfg)
    assert 0.9 < float(aux) < 2.5
    # Force collapse to expert 0. The router has no bias, so a constant
    # [10, 0, 0, 0] column only wins for tokens whose feature SUM is
    # positive — on raw gaussian x half the tokens flip away from
    # expert 0 and aux lands at exactly 1.0 (the seed's marginal
    # failure). Positive features make the constructed collapse actually
    # collapse for every token.
    xp = jnp.abs(x) + 0.1
    _, aux_bal = moe_ffn(p, xp, cfg)
    p2 = dict(p, router=p["router"] * 0.0 +
              jnp.asarray([[10.0, 0, 0, 0]] * 8))
    _, aux2 = moe_ffn(p2, xp, cfg)
    # collapsed load lands on 2 of 4 experts (top_k=2) -> aux ~= 2,
    # well clear of the balanced ~1.1 — no marginal tolerance
    assert float(aux2) > float(aux_bal) + 0.5
    assert float(aux2) > 1.5


def test_shared_experts_always_contribute():
    cfg = ModelConfig(d_model=8, n_experts=2, top_k=1, d_expert=16,
                      n_shared=1, moe=True, capacity_factor=0.01)
    p = _params(jax.random.PRNGKey(6), 8, 2, 16, shared=1)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, 8))
    out, _ = moe_ffn(p, x, cfg)
    norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (norms > 1e-8).all()   # shared path bypasses dropped routing


def test_moe_grads_flow():
    cfg = ModelConfig(d_model=8, n_experts=4, top_k=2, d_expert=16,
                      moe=True)
    p = _params(jax.random.PRNGKey(8), 8, 4, 16)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 8))

    def loss(p):
        out, aux = moe_ffn(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = jax.tree_util.tree_map(lambda a: float(jnp.abs(a).max()), g)
    assert gn["router"] > 0 and gn["we1"] > 0
