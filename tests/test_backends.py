"""Kernel-backend parity (DESIGN.md §10): the xla reference and the
pallas backend (interpret mode on CPU) must agree BIT-FOR-BIT on every
query family, pinned against the committed golden fixture — the same
inputs the facade parity suite replays. Also covers backend resolution
and the backend-tagged executable-cache keys."""
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
from gen_golden import build_inputs  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "spatial_golden.json")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def inputs():
    x, y, index, q = build_inputs()
    return x, y, index, q


@pytest.fixture(scope="module", params=["xla", "pallas"])
def backend_ex(request, inputs):
    from repro.core import EngineConfig, Executor
    _, _, index, _ = inputs
    ex = Executor(index, config=EngineConfig(backend=request.param))
    assert ex.backend.name == request.param
    return ex


# -- resolution ----------------------------------------------------------

def test_backend_resolution():
    import jax
    from repro.core import PallasBackend, XlaBackend, resolve_backend
    assert resolve_backend("xla").name == "xla"
    assert isinstance(resolve_backend("pallas"), PallasBackend)
    auto = resolve_backend("auto")
    if jax.default_backend() == "tpu":
        assert auto.name == "pallas"
    else:
        assert isinstance(auto, XlaBackend)
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_executor_rejects_unknown_backend(inputs):
    from repro.core import EngineConfig, Executor
    _, _, index, _ = inputs
    with pytest.raises(ValueError):
        Executor(index, config=EngineConfig(backend="cuda"))


def test_stats_record_backend(backend_ex):
    st = backend_ex.stats()
    assert st["backend"] == backend_ex.backend.name


def test_cache_keys_carry_backend(backend_ex, inputs):
    from repro.core import RangeCount
    _, _, _, q = inputs
    backend_ex.run(RangeCount(), q["rects"])
    keys = backend_ex.cache_keys()
    assert keys and all(k[0] == backend_ex.backend.name for k in keys)
    assert all(not k[1] for k in keys)        # no mesh -> never qsharded


# -- bit-for-bit parity against the golden fixture -----------------------

def test_point_parity(backend_ex, inputs, golden):
    from repro.core import PointQuery
    _, _, _, q = inputs
    got = np.asarray(backend_ex.run(PointQuery(), q["qx"], q["qy"]))
    assert got.tolist() == golden["point"]


def test_range_count_parity(backend_ex, inputs, golden):
    from repro.core import RangeCount
    _, _, _, q = inputs
    got = np.asarray(backend_ex.run(RangeCount(), q["rects"]))
    assert got.tolist() == golden["range_count"]


def test_range_query_parity(backend_ex, inputs, golden):
    from repro.core import RangeQuery
    _, _, _, q = inputs
    cnt, vids, ok = backend_ex.run(RangeQuery(), q["rects"],
                                   strict=True)
    assert np.asarray(cnt).tolist() == golden["range_query_cnt"]
    assert np.asarray(vids).tolist() == golden["range_query_vids"]
    assert np.asarray(ok).tolist() == golden["range_query_ok"]


def test_circle_count_parity(backend_ex, inputs, golden):
    from repro.core import CircleQuery
    _, _, _, q = inputs
    got = np.asarray(backend_ex.run(CircleQuery(), q["cx"], q["cy"],
                                    q["cr"], strict=True))
    assert got.tolist() == golden["circle_count"]


def test_knn_parity(backend_ex, inputs, golden):
    from repro.core import Knn
    _, _, _, q = inputs
    d2, vid = backend_ex.run(Knn(k=5), q["qx"], q["qy"], strict=True)
    assert np.asarray(d2).tolist() == golden["knn_d2"]
    assert np.asarray(vid).tolist() == golden["knn_vid"]
    d2e, vide = backend_ex.run(Knn(k=3, mode="exact"), q["qx"][:8],
                               q["qy"][:8])
    assert np.asarray(d2e).tolist() == golden["knn_exact_d2"]
    assert np.asarray(vide).tolist() == golden["knn_exact_vid"]


def test_join_parity(backend_ex, inputs, golden):
    from repro.core import SpatialJoin
    _, _, _, q = inputs
    got = np.asarray(backend_ex.run(SpatialJoin(), q["polys"], q["ne"],
                                    strict=True))
    assert got.tolist() == golden["join_count"]
    full = np.asarray(backend_ex.run(SpatialJoin(mode="full"),
                                     q["polys"], q["ne"]))
    assert full.tolist() == golden["join_count"]


def test_fused_steady_path_parity(backend_ex, inputs, golden):
    """The zero-sync fused programs embed the backend's full-refine
    fallback inside lax.cond — counts must stay golden-exact there
    too (this is the serving hot path the kernels now back)."""
    from repro.core import RangeQuery, SpatialJoin
    _, _, _, q = inputs
    syncs = backend_ex.host_syncs
    cnt, _, _ = backend_ex.run(RangeQuery(), q["rects"])   # fused
    assert backend_ex.host_syncs == syncs
    assert np.asarray(cnt).tolist() == golden["range_query_cnt"]
    jc = np.asarray(backend_ex.run(SpatialJoin(), q["polys"], q["ne"]))
    assert backend_ex.host_syncs == syncs
    assert jc.tolist() == golden["join_count"]
