"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py),
interpret mode on CPU, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_index, fit
from repro.core import keys as CK
from repro.data import spatial as ds
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def part_index():
    x, y = ds.make("taxi", 6000, seed=2)
    part = fit("kdtree", x, y, 4, seed=0)
    idx = build_index(x, y, part)
    return x, y, idx


@pytest.mark.parametrize("n", [7, 128, 1000, 4096])
def test_morton_kernel_sweep(n):
    rng = np.random.default_rng(n)
    qx = jnp.asarray(rng.integers(0, 1 << 11, n), jnp.uint32)
    qy = jnp.asarray(rng.integers(0, 1 << 11, n), jnp.uint32)
    got = np.asarray(ops.morton_encode(qx, qy))
    want = np.asarray(ref.morton_encode(qx, qy))
    assert (got == want).all()


@pytest.mark.parametrize("p", [0, 1, 3])
@pytest.mark.parametrize("nq", [5, 300])
def test_spline_search_kernel_sweep(part_index, p, nq):
    x, y, idx = part_index
    rng = np.random.default_rng(p * 100 + nq)
    q = jnp.asarray(np.sort(rng.integers(0, 1 << 22, nq)), jnp.float32)
    keys_f = CK.keys_to_f32(idx.key[p])
    args = (q, idx.knot_keys[p], idx.knot_pos[p], idx.radix_table[p],
            keys_f, idx.radix_kmin[p], idx.radix_scale[p],
            idx.n_knots[p], idx.count[p])
    kw = dict(probe=idx.probe, radix_bits=idx.radix_bits)
    got = np.asarray(ops.spline_search(*args, **kw))
    want = np.asarray(ref.spline_search(*args, **kw))
    assert (got == want).all()
    # and the oracle itself is a true lower bound
    c = int(idx.count[p])
    truth = np.searchsorted(np.asarray(keys_f)[:c], np.asarray(q),
                            side="left")
    assert (want == truth).all()


@pytest.mark.parametrize("nq", [3, 64, 200])
def test_range_count_kernel_sweep(part_index, nq):
    x, y, idx = part_index
    p = 1
    rng = np.random.default_rng(nq)
    rects = jnp.asarray(
        ds.random_rects(nq, 1e-2, (0, 0, 1, 1), seed=nq))
    n_pad = idx.n_pad
    s = rng.integers(0, n_pad // 2, nq)
    e = s + rng.integers(0, n_pad // 2, nq)
    se = jnp.asarray(np.stack([s, e], 1), jnp.float32)
    got = np.asarray(ops.range_count(rects, se, idx.count[p],
                                     idx.x[p], idx.y[p]))
    want = np.asarray(ref.range_count(rects, se, idx.count[p],
                                      idx.x[p], idx.y[p]))
    assert (got == want).all()


@pytest.mark.parametrize("nq", [3, 64, 200])
def test_circle_count_kernel_sweep(part_index, nq):
    x, y, idx = part_index
    p = 1
    rng = np.random.default_rng(nq + 7)
    ix = rng.integers(0, len(x), nq)
    cx, cy = x[ix], y[ix]
    r = rng.uniform(1e-3, 5e-2, nq).astype(np.float32)
    # query 0: a full-interval circle around a partition point, so the
    # sweep always exercises at least one in-circle match
    cx[0], cy[0], r[0] = float(idx.x[p][0]), float(idx.y[p][0]), 0.01
    rects = jnp.asarray(np.stack([cx - r, cy - r, cx + r, cy + r], 1))
    circ = jnp.asarray(np.stack([cx, cy, r], 1))
    n_pad = idx.n_pad
    s = rng.integers(0, n_pad // 2, nq)
    e = s + rng.integers(0, n_pad // 2, nq)
    s[0], e[0] = 0, n_pad
    se = jnp.asarray(np.stack([s, e], 1), jnp.float32)
    got = np.asarray(ops.circle_count(rects, se, circ, idx.count[p],
                                      idx.x[p], idx.y[p]))
    want = np.asarray(ref.circle_count(rects, se, circ, idx.count[p],
                                       idx.x[p], idx.y[p]))
    assert (got == want).all()
    assert want.sum() > 0      # the sweep actually exercises matches


@pytest.mark.parametrize("nq", [2, 40, 150])
def test_point_probe_kernel_sweep(part_index, nq):
    x, y, idx = part_index
    p = 2
    rng = np.random.default_rng(nq + 3)
    c = int(idx.count[p])
    # half real partition points (must be found), half misses
    pos = rng.integers(0, c, nq)
    keys_f = np.asarray(CK.keys_to_f32(idx.key[p]))
    px, py = np.asarray(idx.x[p]), np.asarray(idx.y[p])
    qx = px[pos].copy()
    qy = py[pos].copy()
    qk = keys_f[pos].copy()
    miss = rng.random(nq) < 0.5
    qx[miss] += 1.0            # same key, wrong coordinate
    probe = idx.probe
    start = np.clip(pos - probe // 2, 0, idx.n_pad - probe)
    lanes = start[:, None] + np.arange(probe)[None, :]
    args = (jnp.asarray(qk), jnp.asarray(qx), jnp.asarray(qy),
            jnp.asarray(keys_f[lanes]), jnp.asarray(px[lanes]),
            jnp.asarray(py[lanes]))
    got = np.asarray(ops.point_probe(*args, probe=probe))
    want = np.asarray(ref.point_probe(*args, probe=probe))
    assert (got == want).all()
    assert ((want > 0) == ~miss).all()


@pytest.mark.parametrize("k", [1, 8, 16])
@pytest.mark.parametrize("nq", [4, 130])
def test_knn_topk_kernel_sweep(part_index, k, nq):
    x, y, idx = part_index
    p = 2
    rng = np.random.default_rng(k * 7 + nq)
    ix = rng.integers(0, len(x), nq)
    qxy = jnp.asarray(np.stack([x[ix], y[ix]], 1))
    gn, gi = ops.knn_topk(qxy, idx.count[p], idx.x[p], idx.y[p], k=k)
    wn, wi = ref.knn_topk(qxy, idx.count[p], idx.x[p], idx.y[p], k=k)
    assert np.allclose(np.asarray(gn), np.asarray(wn), rtol=1e-6)
    for a, b in zip(np.asarray(gi), np.asarray(wi)):
        assert set(a[a >= 0]) == set(b[b >= 0])


@pytest.mark.parametrize("edges", [3, 7, 12])
def test_pip_kernel_sweep(part_index, edges):
    x, y, idx = part_index
    p = 0
    polys, ne = ds.random_polygons(1, (0, 0, 1, 1), seed=edges,
                                   max_edges=edges)
    got = np.asarray(ops.point_in_polygon(polys[0], ne[0],
                                          idx.x[p], idx.y[p]))
    want = np.asarray(ref.point_in_polygon(jnp.asarray(polys[0]), ne[0],
                                           idx.x[p], idx.y[p]))
    assert (got == want).all()


def test_kernels_f32_vs_f64_oracle(part_index):
    """dtype sweep: the f32 kernel's counts match a float64 numpy oracle
    on rect containment (coords are exactly representable)."""
    x, y, idx = part_index
    p = 1
    rects = ds.random_rects(32, 1e-2, (0, 0, 1, 1), seed=99)
    se = np.stack([np.zeros(32), np.full(32, idx.n_pad)], 1)
    got = np.asarray(ops.range_count(
        jnp.asarray(rects), jnp.asarray(se, jnp.float32),
        idx.count[p], idx.x[p], idx.y[p]))
    c = int(idx.count[p])
    px = np.asarray(idx.x[p][:c], np.float64)
    py = np.asarray(idx.y[p][:c], np.float64)
    want = np.array([np.sum((px >= r[0]) & (px <= r[2]) &
                            (py >= r[1]) & (py <= r[3]))
                     for r in np.asarray(rects, np.float64)])
    assert (got == want).all()
