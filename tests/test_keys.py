import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import keys as K


def test_spread_compact_roundtrip():
    v = jnp.arange(0, 1 << 12, dtype=jnp.uint32)
    assert (K.compact_bits(K.spread_bits(v)) == v).all()


def test_morton_roundtrip():
    rng = np.random.default_rng(0)
    qx = jnp.asarray(rng.integers(0, 1 << 11, 1000), jnp.uint32)
    qy = jnp.asarray(rng.integers(0, 1 << 11, 1000), jnp.uint32)
    dx, dy = K.morton_decode(K.morton_encode(qx, qy))
    assert (dx == qx).all() and (dy == qy).all()


@given(st.integers(0, 2047), st.integers(0, 2047),
       st.integers(0, 2047), st.integers(0, 2047))
def test_morton_jointly_monotone(x1, y1, dx, dy):
    """x1<=x2 and y1<=y2 => z1 <= z2 — the property that makes the
    morton interval [z(lo), z(hi)] cover a rectangle (paper §4.2)."""
    x2 = min(x1 + dx, 2047)
    y2 = min(y1 + dy, 2047)
    z1 = int(K.morton_encode(jnp.uint32(x1), jnp.uint32(y1)))
    z2 = int(K.morton_encode(jnp.uint32(x2), jnp.uint32(y2)))
    assert z1 <= z2


def test_rect_key_range_covers_members():
    spec = K.KeySpec(bounds=(0.0, 0.0, 1.0, 1.0))
    rng = np.random.default_rng(1)
    pts = rng.random((500, 2)).astype(np.float32)
    rect = jnp.asarray([0.2, 0.3, 0.6, 0.7], jnp.float32)
    klo, khi = K.rect_key_range(rect, spec)
    keys = K.make_keys(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]),
                       spec)
    inside = ((pts[:, 0] >= 0.2) & (pts[:, 0] <= 0.6) &
              (pts[:, 1] >= 0.3) & (pts[:, 1] <= 0.7))
    k = np.asarray(keys)
    assert (k[inside] >= int(klo)).all() and (k[inside] <= int(khi)).all()


def test_keys_exact_in_f32():
    spec = K.KeySpec()
    assert spec.key_bits <= 24
    big = jnp.uint32((1 << spec.key_bits) - 1)
    assert int(K.keys_to_f32(big)) == int(big)
