"""Gradient compression: quantization bounds + error-feedback recovery."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.train.compress import (dequantize_int8, ef_compress_grads,
                                  init_residuals, quantize_int8)


@given(st.integers(1, 2000), st.integers(0, 5))
@settings(max_examples=20)
def test_quantize_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(n) * 10.0 ** float(rng.integers(-3, 3)),
                    jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.shape)
    # per-block error <= scale/2 = max|block|/254
    blocks = np.asarray(jnp.pad(g, (0, (-n) % 256)).reshape(-1, 256))
    bound = np.abs(blocks).max(axis=1) / 254.0 + 1e-9
    err = np.abs(np.asarray(back) - np.asarray(g))
    err_b = np.pad(err, (0, (-n) % 256)).reshape(-1, 256).max(axis=1)
    assert (err_b <= bound * 1.01).all()


def test_error_feedback_mean_converges():
    """With EF, the time-average of compressed syncs converges to the
    true mean gradient (EF-SGD property)."""
    n_workers = 4
    rng = np.random.default_rng(0)
    true = rng.standard_normal((n_workers, 64)).astype(np.float32)

    def one_round(res):
        def worker(g, r):
            gs, new_r = ef_compress_grads(
                {"g": g}, {"g": r}, axis_name="pod")
            return gs["g"], new_r["g"]

        return jax.vmap(worker, axis_name="pod")(
            jnp.asarray(true), res)

    res = jnp.zeros((n_workers, 64), jnp.float32)
    acc = np.zeros(64)
    rounds = 30
    for _ in range(rounds):
        synced, res = one_round(res)
        acc += np.asarray(synced[0])
    avg = acc / rounds
    want = true.mean(axis=0)
    assert np.abs(avg - want).max() < 0.05


def test_residual_shapes():
    params = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((7,))}
    res = init_residuals(params)
    assert res["w"].shape == (3, 4) and res["b"].shape == (7,)
