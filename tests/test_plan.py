"""Plan/executor layer: cache-key canonicalization, executable-cache
eviction bound, zero-host-sync steady-state dispatch, and
escalation-fallback exactness under adversarial skew."""
import numpy as np
import pytest

from conftest import range_oracle
from repro.core import (CircleQuery, EngineConfig, Executor, Knn,
                        PointQuery, RangeCount, RangeQuery, SpatialJoin,
                        build_index, fit)
from repro.data import spatial as ds


@pytest.fixture(scope="module")
def executor(built_index):
    x, y, part, idx = built_index
    return x, y, part, Executor(idx)


# -- QuerySpec canonicalization ------------------------------------------

def test_spec_equality_and_keys():
    assert RangeQuery() == RangeQuery(cap=None)
    assert RangeQuery(cap=np.int64(64)) == RangeQuery(cap=64)
    assert Knn(k=np.int32(5)) == Knn(k=5)
    assert hash(Knn(k=5, mode="pruned")) == hash(Knn(k=5))
    assert Knn(k=5).plan_key() != Knn(k=7).plan_key()
    assert Knn(k=5).sticky_key() == Knn(k=5, mode="pruned").sticky_key()
    assert CircleQuery() == CircleQuery(materialize=False)
    assert CircleQuery(materialize=True).plan_key() != \
        CircleQuery().plan_key()
    # every RangeQuery shares one adaptive state, caps included
    assert RangeQuery(cap=32).sticky_key() == RangeQuery().sticky_key()
    assert PointQuery() == PointQuery()
    assert SpatialJoin() == SpatialJoin(mode="windowed")


def test_spec_validation():
    with pytest.raises(ValueError):
        Knn(k=0)
    with pytest.raises(ValueError):
        Knn(k=3, mode="approx")
    with pytest.raises(ValueError):
        SpatialJoin(mode="hash")
    with pytest.raises(ValueError):
        RangeQuery(cap=-4)


def test_equal_specs_share_one_executable(executor):
    x, y, part, ex = executor
    rects = ds.random_rects(8, 1e-4, part.bounds, seed=1, centers=(x, y))
    n0 = ex.stats()["cache_size"]
    ex.run(RangeQuery(), rects, strict=True)
    n1 = ex.stats()["cache_size"]
    assert n1 > n0                      # first run compiles
    # a DIFFERENT but equal spec instance must hit the same executable
    ex.run(RangeQuery(cap=None), rects, strict=True)
    ex.run(RangeQuery(), rects, strict=True)
    assert ex.stats()["cache_size"] == n1


def test_run_arg_arity_checked(executor):
    _, _, _, ex = executor
    with pytest.raises(TypeError):
        ex.run(PointQuery(), np.zeros(4, np.float32))


# -- zero-host-sync steady state -----------------------------------------

def test_sticky_hit_runs_without_host_sync(built_index):
    x, y, part, idx = built_index
    ex = Executor(idx)
    rects = ds.random_rects(8, 1e-4, part.bounds, seed=2, centers=(x, y))
    qx, qy = x[:8], y[:8]
    polys, ne = ds.random_polygons(6, part.bounds, seed=3)

    warm = [(RangeQuery(), rects), (Knn(k=5), qx, qy),
            (SpatialJoin(), polys, ne), (CircleQuery(), qx, qy,
                                         np.full(8, 0.03, np.float32))]
    ex.run_batch(warm)                   # cold: establishes sticky tiers
    assert ex.host_syncs > 0
    syncs = ex.host_syncs

    out = ex.run_batch(warm)             # steady: fused, zero host syncs
    assert ex.host_syncs == syncs
    # non-adaptive specs never sync either
    ex.run(PointQuery(), qx, qy)
    ex.run(RangeCount(), rects)
    assert ex.host_syncs == syncs

    # ... and the zero-sync results are still exact
    cnt, _, ok = out[0]
    assert bool(np.asarray(ok).all())
    assert (np.asarray(cnt) == range_oracle(x, y, rects)).all()
    d2 = np.sort(np.asarray(out[1][0]), axis=1)
    want = np.sort((x[None, :] - qx[:, None]) ** 2 +
                   (y[None, :] - qy[:, None]) ** 2, axis=1)[:, :5]
    assert np.allclose(d2, want, rtol=1e-5, atol=1e-10)


def test_fused_fallback_stays_exact_on_overflow(built_index):
    """Zero-sync mode with a sticky cap that's too small: the on-device
    lax.cond fallback must keep counts exact anyway."""
    x, y, part, idx = built_index
    ex = Executor(idx, config=EngineConfig(range_cap=2, range_cand=2))
    easy = ds.random_rects(8, 1e-6, part.bounds, seed=4, centers=(x, y))
    hard = ds.random_rects(8, 5e-2, part.bounds, seed=5, centers=(x, y))
    ex.run(RangeQuery(), easy, strict=True)     # sticky at a small tier
    syncs = ex.host_syncs
    cnt, _, ok = ex.run(RangeQuery(), hard)     # overflows the window
    assert ex.host_syncs == syncs               # still no host sync
    assert (np.asarray(cnt) == range_oracle(x, y, hard)).all()
    assert not bool(np.asarray(ok).all())       # materialization flagged


# -- escalation + eviction -----------------------------------------------

def test_escalation_exact_on_adversarial_skew():
    """All candidate windows overflow the initial cap: the shared policy
    must escalate (or fall back) and still return oracle-exact results."""
    rng = np.random.default_rng(0)
    n = 4000
    # a single dense blob: every partition's learned interval for a rect
    # over the blob vastly exceeds a cap of 2
    x = (0.5 + rng.normal(0, 1e-3, n)).astype(np.float32)
    y = (0.5 + rng.normal(0, 1e-3, n)).astype(np.float32)
    part = fit("kdtree", x, y, 4, seed=0)
    idx = build_index(x, y, part)
    cfg = EngineConfig(range_cap=2, range_cand=1, join_cap=2,
                       join_cand=1, knn_cap=2, circle_cap=2,
                       circle_cand=1)
    ex = Executor(idx, config=cfg)

    rects = np.asarray([[0.49, 0.49, 0.51, 0.51],
                        [0.0, 0.0, 1.0, 1.0]], np.float32)
    cnt, vids, ok = ex.run(RangeQuery(), rects, strict=True)
    assert bool(np.asarray(ok).all())
    assert (np.asarray(cnt) == range_oracle(x, y, rects)).all()
    got = set(np.asarray(vids)[1][np.asarray(vids)[1] >= 0])
    assert got == set(range(n))

    d2, _ = ex.run(Knn(k=7), x[:4], y[:4], strict=True)
    want = np.sort((x[None, :] - x[:4, None]) ** 2 +
                   (y[None, :] - y[:4, None]) ** 2, axis=1)[:, :7]
    assert np.allclose(np.sort(np.asarray(d2), 1), want,
                       rtol=1e-5, atol=1e-12)

    cx = x[:3]
    cy = y[:3]
    r = np.full(3, 0.004, np.float32)
    got_c = np.asarray(ex.run(CircleQuery(), cx, cy, r, strict=True))
    want_c = np.array([np.sum((x - a) ** 2 + (y - b) ** 2 <= rr * rr)
                       for a, b, rr in zip(cx, cy, r)])
    assert (got_c == want_c).all()


def test_maintain_escalates_overflowed_sticky_tier(built_index):
    """Serving re-tune loop: zero-sync runs stash their ok flags; an
    off-hot-path maintain() escalates tiers that overflowed, so a
    workload shift doesn't truncate materialization forever."""
    x, y, part, idx = built_index
    ex = Executor(idx, config=EngineConfig(range_cap=2, range_cand=2))
    easy = ds.random_rects(8, 1e-6, part.bounds, seed=6, centers=(x, y))
    hard = ds.random_rects(8, 1e-2, part.bounds, seed=7, centers=(x, y))
    base = RangeQuery().sticky_key()
    ex.run(RangeQuery(), easy, strict=True)      # small sticky tier
    tier0 = ex._sticky[base]
    _, _, ok = ex.run(RangeQuery(), hard)        # zero-sync, overflows
    assert not bool(np.asarray(ok).all())
    while ex.maintain():                         # escalate until settled
        cnt, vids, ok = ex.run(RangeQuery(), hard)
    assert ex._sticky[base] != tier0
    assert bool(np.asarray(ok).all())            # window now complete
    assert (np.asarray(cnt) == range_oracle(x, y, hard)).all()
    # a clean steady run stashes ok=True; maintain is then a no-op
    ex.run(RangeQuery(), hard)
    assert ex.maintain() == {}


def test_user_cap_never_moves_the_shared_sticky_tier(built_index):
    """A one-off RangeQuery(cap=N) must not downgrade the serving tier
    (which would evict the steady fused executable and churn compiles)."""
    x, y, part, idx = built_index
    ex = Executor(idx)
    base = RangeQuery().sticky_key()
    rects = ds.random_rects(6, 1e-2, part.bounds, seed=21,
                            centers=(x, y))
    ex.run(RangeQuery(), rects, strict=True)     # settle a real tier
    tier = ex._sticky[base]
    easy = ds.random_rects(4, 1e-6, part.bounds, seed=22,
                           centers=(x, y))
    cnt, _, ok = ex.run(RangeQuery(cap=4), easy, strict=True)
    assert (np.asarray(cnt) == range_oracle(x, y, easy)).all()
    assert ex._sticky[base] == tier              # tier untouched
    assert ("w", tier) in ex.cache_variants(base)  # exec not evicted


def test_cache_evicts_superseded_cap_variants(built_index):
    """Escalation must not leak one compiled program per tier: after the
    sticky tier settles, at most the sticky + initial tiers remain."""
    x, y, part, idx = built_index
    ex = Executor(idx, config=EngineConfig(range_cap=2, range_cand=1))
    base = RangeQuery().sticky_key()
    for sel in (1e-6, 1e-4, 1e-3, 1e-2, 1e-1):   # force repeated escalation
        rects = ds.random_rects(6, sel, part.bounds,
                                seed=int(sel * 1e7), centers=(x, y))
        cnt, _, ok = ex.run(RangeQuery(), rects, strict=True)
        assert bool(np.asarray(ok).all())
        assert (np.asarray(cnt) == range_oracle(x, y, rects)).all()
        tiers = {v for _, v in ex.cache_variants(base)}
        assert len(tiers) <= 2, tiers            # sticky + initial only
    assert ex._sticky[base] != (2, 1)            # escalation did happen


def test_facade_and_run_share_sticky_state(built_index):
    x, y, part, idx = built_index
    from repro.core import SpatialEngine
    eng = SpatialEngine(idx)
    rects = ds.random_rects(6, 1e-4, part.bounds, seed=9, centers=(x, y))
    eng.range_query(rects)                       # facade warms sticky
    syncs = eng.executor.host_syncs
    cnt, _, _ = eng.run(RangeQuery(), rects)     # plan API: fused path
    assert eng.executor.host_syncs == syncs
    assert (np.asarray(cnt) == range_oracle(x, y, rects)).all()
