import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.radix import build_radix, radix_locate, \
    windowed_segment_search
from repro.core.spline import build_spline


@given(st.lists(st.integers(0, (1 << 22) - 1), min_size=4, max_size=300),
       st.integers(2, 10))
@settings(max_examples=30)
def test_radix_window_contains_successor(keys, bits):
    """For any query key, the radix window [T[j], T[j+1]] must contain
    the successor knot (first knot >= key) — paper Alg. 2 contract."""
    keys = np.sort(np.unique(np.asarray(keys, np.int64)))
    if len(keys) < 2:
        return
    kf = jnp.asarray(keys, jnp.float32)
    sp = build_spline(kf, jnp.ones(len(keys), bool), eps=4,
                      m_pad=len(keys) + 2)
    n = int(sp["n_knots"])
    rad = build_radix(sp["knot_keys"], sp["n_knots"], bits=bits)
    queries = jnp.asarray(
        np.unique(np.concatenate([keys, keys + 1, keys - 1])).clip(
            0, (1 << 22) - 1), jnp.float32)
    lo, hi = radix_locate(rad, queries, sp["n_knots"], bits=bits)
    kk = np.asarray(sp["knot_keys"])[:n]
    for q, l, h in zip(np.asarray(queries), np.asarray(lo),
                       np.asarray(hi)):
        succ = np.searchsorted(kk, q, side="left")
        if succ >= n:
            continue  # beyond all knots: clamped segment is fine
        assert l <= succ <= h + 1


def test_windowed_segment_matches_searchsorted():
    rng = np.random.default_rng(0)
    keys = np.sort(rng.choice(1 << 22, 500, replace=False))
    kf = jnp.asarray(keys, jnp.float32)
    sp = build_spline(kf, jnp.ones(len(keys), bool), eps=16, m_pad=600)
    rad = build_radix(sp["knot_keys"], sp["n_knots"], bits=8)
    q = jnp.asarray(rng.integers(0, 1 << 22, 200), jnp.float32)
    lo, hi = radix_locate(rad, q, sp["n_knots"], bits=8)
    seg = windowed_segment_search(sp["knot_keys"], q, lo, hi)
    n = int(sp["n_knots"])
    kk = np.asarray(sp["knot_keys"])[:n]
    want = np.clip(np.searchsorted(kk, np.asarray(q), side="right") - 1,
                   0, n - 2)
    got = np.clip(np.asarray(seg), 0, n - 2)
    assert (got == want).all()
