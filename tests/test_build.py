import numpy as np

from repro.core import keys as K


def test_layout_sorted_and_padded(built_index):
    x, y, part, idx = built_index
    keys = np.asarray(idx.key)
    counts = np.asarray(idx.count)
    sentinel = idx.key_spec.sentinel
    for p in range(idx.num_partitions):
        c = counts[p]
        row = keys[p]
        assert (np.diff(row[:c].astype(np.int64)) >= 0).all()
        assert (row[c:] == sentinel).all()
    assert counts.sum() == len(x)


def test_vids_are_permutation(built_index):
    x, y, part, idx = built_index
    vid = np.asarray(idx.vid)
    valid = vid[vid >= 0]
    assert len(valid) == len(x)
    assert len(np.unique(valid)) == len(x)


def test_points_in_their_partition(built_index):
    x, y, part, idx = built_index
    bounds = np.asarray(idx.part_bounds)
    xs = np.asarray(idx.x)
    ys = np.asarray(idx.y)
    counts = np.asarray(idx.count)
    for p in range(idx.num_partitions - 1):  # skip overflow
        c = counts[p]
        if c == 0:
            continue
        bx = bounds[p]
        assert (xs[p, :c] >= bx[0] - 1e-5).all()
        assert (xs[p, :c] <= bx[2] + 1e-5).all()
        assert (ys[p, :c] >= bx[1] - 1e-5).all()
        assert (ys[p, :c] <= bx[3] + 1e-5).all()


def test_keys_match_coords(built_index):
    x, y, part, idx = built_index
    p = 0
    c = int(idx.count[0])
    import jax.numpy as jnp
    recomputed = K.make_keys(idx.x[p, :c], idx.y[p, :c], idx.key_spec)
    assert (np.asarray(recomputed) == np.asarray(idx.key[p, :c])).all()


def test_index_is_lightweight(built_index):
    """Spline+radix model must be a small fraction of the data (the
    paper's 'lightweight' claim). The radix tables are a fixed
    (2^b + 2) x 4 bytes per partition; the data-dependent part (spline
    knots) must stay well under 10% of the data."""
    x, y, part, idx = built_index
    data_bytes = len(x) * 4 * 3
    sizes = idx.size_bytes()
    radix_fixed = idx.radix_table.size * 4
    assert sizes["local_model"] - radix_fixed < 0.10 * data_bytes
    assert sizes["local_model"] < data_bytes
    assert sizes["global_index"] < 4096
