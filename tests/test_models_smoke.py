"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.tokens import make_batch
from repro.models import build_model


@pytest.fixture(scope="module")
def states():
    return {}


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, seed=1)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg, model, params, batch = _setup(arch)
    loss = jax.jit(model.train_loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    # one optimizer step moves the loss
    from repro.train import make_train_step
    from repro.train.optimizer import adamw_init
    step = make_train_step(model, peak_lr=1e-3, warmup=1, total_steps=10)
    p2, opt2, m = step.jit(params, adamw_init(params), batch)
    assert jnp.isfinite(m["loss"])
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg, model, params, batch = _setup(arch)
    if cfg.family == "encdec":
        tgt = batch["tokens"]
        lg_full, _, _ = model.forward(params, batch)
        b1 = dict(batch, tokens=tgt[:, :-1])
        _, cache = model.prefill(params, b1, max_len=tgt.shape[1])
        lg_dec, _ = model.decode_step(params, cache, tgt[:, -1:],
                                      jnp.int32(tgt.shape[1] - 1))
    elif cfg.family == "rwkv6":
        lg_full, _ = model._forward(params, batch["tokens"],
                                    model.init_state(2))
        b1 = dict(batch, tokens=batch["tokens"][:, :-1])
        _, cache = model.prefill(params, b1)
        lg_dec, _ = model.decode_step(params, cache,
                                      batch["tokens"][:, -1:], None)
    else:
        ntok = batch["tokens"].shape[1]
        off = cfg.n_patches if cfg.patch_input else 0
        lg_full = model.forward(params, batch)[0]
        b1 = dict(batch, tokens=batch["tokens"][:, :-1])
        _, cache = model.prefill(params, b1, max_len=off + ntok)
        lg_dec, _ = model.decode_step(params, cache,
                                      batch["tokens"][:, -1:],
                                      jnp.int32(off + ntok - 1))
    diff = float(jnp.max(jnp.abs(lg_dec[:, 0] - lg_full[:, -1])))
    assert diff < 0.05, f"{arch}: decode diverges from forward ({diff})"


@pytest.mark.parametrize("arch", ["gemma3_4b", "hymba_1_5b"])
def test_sliding_window_pattern(arch):
    cfg = get_config(arch, smoke=True)
    wins = [cfg.window_for_layer(i) for i in range(cfg.n_layers)]
    assert 0 in wins, "needs at least one global layer"
    assert cfg.window in wins, "needs local layers"


def test_full_configs_match_assignment():
    """Spot-check the published hyperparameters."""
    c = get_config("deepseek-v2-lite-16b")
    assert (c.n_layers, c.d_model, c.n_heads) == (27, 2048, 16)
    assert c.kv_lora == 512 and c.moe and c.top_k == 6
    c = get_config("dbrx-132b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == \
        (40, 6144, 16, 4)
    c = get_config("rwkv6-3b")
    assert c.family == "rwkv6" and c.d_model == 2560 and c.n_layers == 32
    c = get_config("gemma3-4b")
    assert c.vocab == 262144 and c.global_every == 6
    c = get_config("qwen2.5-3b")
    assert c.qkv_bias and c.n_kv_heads == 2
    c = get_config("internlm2-20b")
    assert c.d_ff == 16384 and c.vocab == 92544
    c = get_config("minicpm3-4b")
    assert c.attn == "mla" and c.n_layers == 62
    c = get_config("seamless-m4t-medium")
    assert c.enc_layers == 12 and c.dec_layers == 12 and \
        c.vocab == 256206
    c = get_config("hymba-1.5b")
    assert c.ssm_state == 16 and c.n_heads == 25
    c = get_config("phi-3-vision-4.2b")
    assert c.patch_input and c.d_model == 3072


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "deepseek_v2_lite_16b"])
def test_param_count_scale(arch):
    """Full configs land near their published parameter counts."""
    cfg = get_config(arch)
    n = cfg.param_count()
    target = {"qwen2_5_3b": 3.1e9, "deepseek_v2_lite_16b": 15.7e9}[arch]
    assert 0.7 * target < n < 1.35 * target, n
