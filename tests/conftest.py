import os
import sys

# keep smoke tests on ONE device — the 512-device override belongs ONLY
# to the dry-run (see launch/dryrun.py); distributed engine tests spawn
# subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

try:                                    # property tests are optional: the
    from hypothesis import settings     # suite must collect even without
                                        # the hypothesis wheel
    settings.register_profile("fast", max_examples=25, deadline=None)
    settings.load_profile("fast")
    HAVE_HYPOTHESIS = True
except ImportError:                     # not installed: skip the
    HAVE_HYPOTHESIS = False             # property-test files
    collect_ignore = ["test_compress.py", "test_keys.py",
                      "test_radix.py", "test_spline.py"]


@pytest.fixture(scope="session")
def small_spatial():
    from repro.data import spatial as ds
    x, y = ds.make("gaussian", 12000, seed=7)
    return x, y


@pytest.fixture(scope="session")
def built_index(small_spatial):
    from repro.core import build_index, fit
    x, y = small_spatial
    part = fit("kdtree", x, y, 12, seed=0)
    return x, y, part, build_index(x, y, part)


def range_oracle(x, y, rects):
    return np.array([np.sum((x >= r[0]) & (x <= r[2]) &
                            (y >= r[1]) & (y <= r[3])) for r in rects])


def knn_oracle(x, y, qx, qy, k):
    d2 = (x[None, :] - qx[:, None]) ** 2 + (y[None, :] - qy[:, None]) ** 2
    return np.sort(d2, axis=1)[:, :k]


def pip_oracle(px, py, poly, n):
    inside = np.zeros(len(px), bool)
    j = n - 1
    for i in range(n):
        xi, yi = poly[i]
        xj, yj = poly[j]
        c = (((yi > py) != (yj > py)) &
             (px < (xj - xi) * (py - yi) / (yj - yi + 1e-30) + xi))
        inside ^= c
        j = i
    return inside
