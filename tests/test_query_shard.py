"""Query-axis sharding (DESIGN.md §10): a batch above
EngineConfig.query_shard_threshold must compile a query-sharded
executable (asserted via the plan.exec_key cache-key layout) and return
results bitwise-identical to the unsharded path, padding included.

Runs in a SUBPROCESS because XLA device count must be set before jax
initializes (conftest keeps the main test process at 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax
from repro.core import *
from repro.data import spatial as ds

mesh = jax.make_mesh((2, 4), ("data", "query"))
x, y = ds.make("taxi", 20000, seed=2)
part = fit("kdtree", x, y, 24)
idx = build_index(x, y, part)

single = Executor(idx)
cfg = EngineConfig(query_shard_threshold=16)
qex = Executor(idx, mesh=mesh, part_axis="data", query_axis="query",
               config=cfg)

rng = np.random.default_rng(0)
n_q = 42   # NOT a multiple of the 4-way query axis: exercises padding
ix = rng.integers(0, len(x), n_q)
qx, qy = x[ix], y[ix]
rects = ds.random_rects(n_q, 1e-3, part.bounds, seed=3, centers=(x, y))
polys, ne = ds.random_polygons(18, part.bounds, seed=5)

# mixed batch through run_batch: every result bitwise == unsharded
reqs = [(PointQuery(), qx, qy), (RangeCount(), rects),
        (RangeQuery(), rects), (Knn(k=7), qx, qy),
        (SpatialJoin(), polys, ne)]
want = single.run_batch(reqs, strict=True)
got = qex.run_batch(reqs, strict=True)
for w, g in zip(want, got):
    wl = w if isinstance(w, tuple) else (w,)
    gl = g if isinstance(g, tuple) else (g,)
    for a, b in zip(wl, gl):
        assert (np.asarray(a) == np.asarray(b)).all()

# cache-key check: the compiled executables are the query-sharded
# variants (plan.exec_key layout: key[1] is the qshard flag)
qkeys = [k for k in qex.cache_keys() if k[1]]
assert qkeys, qex.cache_keys()
assert qex.stats()["qshard_executables"] == len(qkeys)

# a below-threshold batch compiles (and uses) the UNSHARDED variant
qex.run(PointQuery(), qx[:8], qy[:8])
plain = [k for k in qex.cache_keys() if not k[1] and k[2] == ("point",)]
assert len(plain) == 1

# the fused zero-sync steady path also query-shards, stays exact, and
# still never syncs with the host
syncs = qex.host_syncs
c2, v2, o2 = qex.run(RangeQuery(), rects)
assert qex.host_syncs == syncs
assert (np.asarray(c2) == np.asarray(want[2][0])).all()
assert (np.asarray(v2) == np.asarray(want[2][1])).all()

# validation: a query axis that is also a partition axis is rejected
try:
    Executor(idx, mesh=mesh, part_axis="data", query_axis="data")
    raise SystemExit("expected ValueError")
except ValueError:
    pass
try:
    Executor(idx, query_axis="query")
    raise SystemExit("expected ValueError (no mesh)")
except ValueError:
    pass
print("QSHARD-OK")
"""


@pytest.mark.slow
def test_query_sharded_batches_match_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "QSHARD-OK" in out.stdout, out.stdout + out.stderr
