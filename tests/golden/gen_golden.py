"""Regenerate the golden parity fixture for the SpatialEngine facade.

Run from the repo root against a KNOWN-GOOD revision (originally the
pre-plan/executor seed engine) and commit the JSON. The parity suite
(tests/test_executor_parity.py) replays the same deterministic inputs
through the current facade and requires bitwise-identical outputs.

    PYTHONPATH=src python tests/golden/gen_golden.py
"""
import json
import os

import numpy as np


def build_inputs():
    from repro.core import build_index, fit
    from repro.data import spatial as ds

    x, y = ds.make("gaussian", 12000, seed=7)
    part = fit("kdtree", x, y, 12, seed=0)
    index = build_index(x, y, part)

    rng = np.random.default_rng(11)
    ix = rng.integers(0, len(x), 32)
    qx = np.concatenate([x[ix[:16]],
                         rng.random(16).astype(np.float32) * 2 - 0.5])
    qy = np.concatenate([y[ix[:16]],
                         rng.random(16).astype(np.float32) * 2 - 0.5])
    rects = ds.random_rects(16, 1e-4, part.bounds, seed=13,
                            centers=(x, y))
    cx, cy = x[ix[16:28]], y[ix[16:28]]
    cr = np.full(12, 0.04, np.float32)
    polys, ne = ds.random_polygons(8, part.bounds, seed=17)
    return (x, y, index, dict(qx=qx, qy=qy, rects=rects, cx=cx, cy=cy,
                              cr=cr, polys=polys, ne=ne))


def main():
    from repro.core import SpatialEngine

    x, y, index, q = build_inputs()
    eng = SpatialEngine(index)
    out = {}
    out["point"] = np.asarray(eng.point_query(q["qx"], q["qy"])).tolist()
    out["range_count"] = np.asarray(eng.range_count(q["rects"])).tolist()
    cnt, vids, ok = eng.range_query(q["rects"])
    out["range_query_cnt"] = np.asarray(cnt).tolist()
    out["range_query_vids"] = np.asarray(vids).tolist()
    out["range_query_ok"] = np.asarray(ok).tolist()
    out["circle_count"] = np.asarray(
        eng.circle_count(q["cx"], q["cy"], q["cr"])).tolist()
    d2, vid = eng.knn(q["qx"], q["qy"], 5, mode="pruned")
    out["knn_d2"] = np.asarray(d2).tolist()
    out["knn_vid"] = np.asarray(vid).tolist()
    d2e, vide = eng.knn(q["qx"][:8], q["qy"][:8], 3, mode="exact")
    out["knn_exact_d2"] = np.asarray(d2e).tolist()
    out["knn_exact_vid"] = np.asarray(vide).tolist()
    out["join_count"] = np.asarray(
        eng.join_count(q["polys"], q["ne"])).tolist()

    path = os.path.join(os.path.dirname(__file__), "spatial_golden.json")
    with open(path, "w") as f:
        json.dump(out, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
