"""Scheduler read-your-writes ordering (DESIGN.md §12).

FIFO-with-write-barriers semantics over the epoch-versioned mutable
index (§11): a read enqueued AFTER an ``InsertBatch``/``DeleteBatch``
acknowledges the write's epoch (``Ticket.epoch`` >= the write's) and
observes its effect; a read enqueued BEFORE it may not. The barrier
holds across the ingest-stream merge fast path (consecutive inserts
coalesced into one update dispatch, vids routed per request) and
across an occupancy-triggered compaction — which must run at
queue-idle time only, never between queued requests.
"""
import numpy as np
import pytest

from repro.core import (DeleteBatch, EngineConfig, InsertBatch,
                        PointQuery, RangeCount, build_index, fit)
from repro.data import spatial as ds
from repro.serve import SpatialServeSession

N = 1500


@pytest.fixture()
def setup():
    x, y = ds.make("gaussian", N, seed=5)
    part = fit("kdtree", x, y, 4, seed=0)
    s = SpatialServeSession(build_index(x, y, part),
                            config=EngineConfig(delta_cap=32))
    sched = s.scheduler(start=False)
    return x, y, part, s, sched


def _pt(v):
    return np.asarray([v], np.float32)


def test_read_after_insert_observes_epoch(setup):
    x, y, part, s, sched = setup
    nx, ny = _pt(0.123456), _pt(0.654321)     # not in the dataset
    t_pre = sched.submit(PointQuery(), nx, ny)
    t_w = sched.submit(InsertBatch(), nx, ny)
    t_post = sched.submit(PointQuery(), nx, ny)
    sched.drain()
    # the read enqueued BEFORE the write may not observe it ...
    assert not bool(t_pre.result()[0])
    assert t_pre.epoch < t_w.epoch
    # ... the read enqueued AFTER it MUST: epoch acknowledged + visible
    assert t_w.epoch == 1 and t_post.epoch >= t_w.epoch
    assert bool(t_post.result()[0])
    sched.close()


def test_read_after_delete_observes_epoch(setup):
    x, y, part, s, sched = setup
    qx, qy = _pt(x[7]), _pt(y[7])              # a real resident point
    t0 = sched.submit(PointQuery(), qx, qy)
    t_w = sched.submit(DeleteBatch(), qx, qy)
    t1 = sched.submit(PointQuery(), qx, qy)
    sched.drain()
    assert bool(t0.result()[0]) and not bool(t1.result()[0])
    assert int(t_w.result()) >= 1              # removed count routed
    assert t0.epoch < t_w.epoch <= t1.epoch
    sched.close()


def test_consecutive_inserts_merge_and_route_vids(setup):
    x, y, part, s, sched = setup
    ax = np.asarray([0.111, 0.222, 0.333], np.float32)
    ay = np.asarray([0.444, 0.555, 0.666], np.float32)
    bx = np.asarray([0.777, 0.888], np.float32)
    by = np.asarray([0.112, 0.223], np.float32)
    ta = sched.submit(InsertBatch(), ax, ay)
    tb = sched.submit(InsertBatch(), bx, by)
    t_read = sched.submit(PointQuery(), np.concatenate([ax, bx]),
                          np.concatenate([ay, by]))
    sched.drain()
    va, vb = np.asarray(ta.result()), np.asarray(tb.result())
    # one merged update dispatch, vids routed back per request
    assert sched.stats()["write_merges"] == 1
    assert va.shape == (3,) and vb.shape == (2,)
    assert len(set(va.tolist() + vb.tolist())) == 5
    assert ta.epoch == tb.epoch                # one merged write epoch
    # the read behind the merged run sees every inserted point
    assert t_read.epoch >= ta.epoch
    assert bool(np.all(t_read.result()))
    sched.close()


def test_reads_never_hoisted_across_write(setup):
    """Interleaved read/write traffic: each read's result reflects
    exactly the writes enqueued before it — FIFO, not batched across
    the barrier (the count goes 0 -> 1 -> 2 as inserts land between)."""
    x, y, part, s, sched = setup
    rect = np.asarray([[0.21, 0.21, 0.29, 0.29]], np.float32)
    # the probe rect is empty in the built dataset? make it so by
    # counting serially first and inserting only fresh interior points
    base = int(np.asarray(s.submit(RangeCount(), rect))[0])
    t0 = sched.submit(RangeCount(), rect)
    sched.submit(InsertBatch(), _pt(0.25), _pt(0.25))
    t1 = sched.submit(RangeCount(), rect)
    sched.submit(InsertBatch(), _pt(0.26), _pt(0.26))
    t2 = sched.submit(RangeCount(), rect)
    sched.drain()
    assert int(np.asarray(t0.result())[0]) == base
    assert int(np.asarray(t1.result())[0]) == base + 1
    assert int(np.asarray(t2.result())[0]) == base + 2
    assert t0.epoch < t1.epoch < t2.epoch
    sched.close()


def test_barrier_across_occupancy_compaction():
    """An insert burst that trips the delta-occupancy threshold
    schedules compaction+re-fit; the scheduler runs it at IDLE time
    (queue empty), never between queued requests, and reads stay exact
    across the epoch/shape handoff."""
    x, y = ds.make("gaussian", N, seed=5)
    part = fit("kdtree", x, y, 4, seed=0)
    s = SpatialServeSession(
        build_index(x, y, part),
        config=EngineConfig(delta_cap=32, delta_occupancy=0.0))
    sched = s.scheduler(start=False)
    nx = np.linspace(0.31, 0.39, 9).astype(np.float32)
    ny = np.linspace(0.61, 0.69, 9).astype(np.float32)
    t_w = sched.submit(InsertBatch(), nx, ny)
    t_r = sched.submit(PointQuery(), nx, ny)
    sched.drain()
    ex = s.executor
    # the zero-threshold occupancy tripped a deferred compaction and
    # drain()'s idle maintenance executed it — with an EMPTY queue
    assert ex.refits == 1 and not ex.stats()["pending_refit"]
    maint = [e for e in sched.events if e[0] == "maintain"]
    assert maint and all(e[1] == 0 for e in maint)
    # ... and strictly after the queued write + read (FIFO preserved)
    kinds = [e[0] for e in sched.events]
    assert kinds.index("maintain") > max(
        i for i, k in enumerate(kinds) if k in ("batch", "write"))
    assert bool(np.all(t_r.result())) and t_r.epoch >= t_w.epoch
    # post-compaction reads observe the refit epoch and stay exact
    t2 = sched.submit(PointQuery(), nx, ny)
    sched.drain()
    assert bool(np.all(t2.result()))
    assert t2.epoch > t_r.epoch                # refit bumped the epoch
    assert sched.stats()["maintain_busy"] == 0
    sched.close()
