import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.spline import build_spline, spline_predict


def _fit(keys_sorted, eps=8, m_pad=None):
    n = len(keys_sorted)
    kf = jnp.asarray(keys_sorted, jnp.float32)
    valid = jnp.ones(n, bool)
    return build_spline(kf, valid, eps=eps, m_pad=m_pad or n + 2)


@given(st.lists(st.integers(0, (1 << 22) - 1), min_size=2, max_size=400))
@settings(max_examples=30)
def test_error_bound_property(keys):
    """|S(key) - first_occurrence_rank| <= eps for every data key —
    the paper's core invariant (eps-bounded spline, §3.2)."""
    keys = np.sort(np.asarray(keys, np.int64))
    eps = 4
    sp = _fit(keys, eps=eps)
    assert not bool(sp["overflow"])
    kf = jnp.asarray(keys, jnp.float32)
    pred = spline_predict(sp["knot_keys"], sp["knot_pos"],
                          sp["n_knots"], kf)
    first_pos = np.searchsorted(keys, keys, side="left")
    err = np.abs(np.asarray(pred) - first_pos)
    assert err.max() <= eps + 1.0  # +1 f32 rounding headroom


def test_knots_monotone_and_compact():
    rng = np.random.default_rng(3)
    keys = np.sort(rng.integers(0, 1 << 22, 5000))
    sp = _fit(keys, eps=32)
    n = int(sp["n_knots"])
    kk = np.asarray(sp["knot_keys"])[:n]
    assert (np.diff(kk) > 0).all()
    # learned index is SMALL relative to data (lightweight claim)
    assert n < len(keys) / 4


def test_max_run_counts_duplicates():
    keys = np.asarray([1, 1, 1, 2, 3, 3, 7, 7, 7, 7, 9])
    sp = _fit(keys, eps=4)
    assert int(sp["max_run"]) == 4


def test_single_key_partition():
    sp = _fit(np.asarray([5, 5, 5]), eps=2)
    pred = spline_predict(sp["knot_keys"], sp["knot_pos"], sp["n_knots"],
                          jnp.float32(5.0))
    assert abs(float(pred) - 0.0) <= 2


def test_overflow_flag():
    # eps=0 on NON-collinear keys forces ~a knot per key; m_pad too
    # small -> overflow flag (build_index raises on it)
    rng = np.random.default_rng(0)
    keys = np.cumsum(rng.integers(1, 9, 100))
    kf = jnp.asarray(keys, jnp.float32)
    sp = build_spline(kf, jnp.ones(100, bool), eps=0, m_pad=10)
    assert bool(sp["overflow"])
