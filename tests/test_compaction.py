"""Compaction-equivalence suite (DESIGN.md §10 invariants).

The windowed refinement pipeline replaced its argsort hot paths with
lax.top_k candidate selection and cumsum stream compaction, and fused
the circle distance refine into the window gather. The invariants those
rewrites must preserve, asserted here across cap/cand tiers and both
kernel backends:

  counts    bitwise-equal to the golden fixture (the exact results the
            pre-compaction pipeline produced);
  id sets   materialized vids equal the exact full-refine sets
            (order-insensitive) whenever the window reported ok, and a
            subset on overflow rows (which the fused serving path
            answers with the exact on-device fallback count);
  demotion  maintain() steps clean sticky tiers back down (and vetoes
            ping-pong).

Plus direct micro-equivalence: the new helpers are bitwise the argsort
implementations they replaced, including overflow rows.
"""
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
from gen_golden import build_inputs  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "spatial_golden.json")
TIERS = [(8, 2), (64, 8), (256, 16)]


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def inputs():
    return build_inputs()


@pytest.fixture(scope="module",
                params=[(b, cap, cand) for b in ("xla", "pallas")
                        for cap, cand in TIERS],
                ids=lambda p: f"{p[0]}-cap{p[1]}-cand{p[2]}")
def tier_ex(request, inputs):
    from repro.core import EngineConfig, Executor
    backend, cap, cand = request.param
    _, _, index, _ = inputs
    cfg = EngineConfig(backend=backend, range_cap=cap, range_cand=cand,
                       circle_cap=cap, circle_cand=cand)
    return Executor(index, config=cfg)


def _exact_rect_sets(x, y, rects):
    return [set(np.flatnonzero((x >= r[0]) & (x <= r[2]) &
                               (y >= r[1]) & (y <= r[3])))
            for r in np.asarray(rects)]


def _exact_circle_sets(x, y, cx, cy, cr):
    return [set(np.flatnonzero((x - a) ** 2 + (y - b) ** 2 <= r * r))
            for a, b, r in zip(np.asarray(cx), np.asarray(cy),
                               np.asarray(cr))]


def test_range_counts_bitwise_and_id_sets_exact(tier_ex, inputs, golden):
    """Whatever tier the ladder starts from, escalation must end on a
    complete window: counts bitwise the golden fixture, vid sets the
    exact full-refine sets."""
    from repro.core import RangeQuery
    x, y, _, q = inputs
    cnt, vids, ok = tier_ex.run(RangeQuery(), q["rects"], strict=True)
    assert np.asarray(cnt).tolist() == golden["range_query_cnt"]
    assert bool(np.asarray(ok).all())
    want = _exact_rect_sets(x, y, q["rects"])
    for row, w in zip(np.asarray(vids), want):
        assert set(row[row >= 0]) == w


def test_circle_counts_bitwise_and_id_sets_exact(tier_ex, inputs,
                                                 golden):
    from repro.core import CircleQuery
    x, y, _, q = inputs
    got = tier_ex.run(CircleQuery(), q["cx"], q["cy"], q["cr"],
                      strict=True)
    assert np.asarray(got).tolist() == golden["circle_count"]
    cnt, vids, ok = tier_ex.run(CircleQuery(materialize=True), q["cx"],
                                q["cy"], q["cr"], strict=True)
    assert np.asarray(cnt).tolist() == golden["circle_count"]
    want = _exact_circle_sets(x, y, q["cx"], q["cy"], q["cr"])
    for row, w, okq in zip(np.asarray(vids), want, np.asarray(ok)):
        got_set = set(row[row >= 0])
        if okq:
            assert got_set == w
        else:
            assert got_set <= w


def test_overflow_rows_fall_back_to_exact_counts(inputs, golden):
    """The fused serving path at a deliberately tiny sticky tier: the
    overflow rows' counts come from the on-device exact fallback
    (bitwise golden), the truncated windows stay subsets."""
    from repro.core import CircleQuery, EngineConfig, Executor
    x, y, index, q = inputs
    ex = Executor(index, config=EngineConfig(circle_cap=2,
                                             circle_cand=1))
    spec = CircleQuery(materialize=True)
    ex._sticky[spec.sticky_key()] = (2, 1)       # deliberately tiny tier
    cnt, vids, ok = ex.run(spec, q["cx"], q["cy"], q["cr"])  # fused
    assert not bool(np.asarray(ok).all())
    assert np.asarray(cnt).tolist() == golden["circle_count"]
    want = _exact_circle_sets(x, y, q["cx"], q["cy"], q["cr"])
    for row, w in zip(np.asarray(vids), want):
        assert set(row[row >= 0]) <= w


def test_maintain_demotes_clean_sticky_tiers(inputs):
    """Online re-tune, downward: after a hard burst escalates the tier,
    demote_after consecutive clean maintain() checks step it back."""
    from repro.core import EngineConfig, Executor, RangeQuery
    from repro.data import spatial as ds
    x, y, index, q = inputs
    cfg = EngineConfig(range_cap=2, range_cand=2, demote_after=2)
    ex = Executor(index, config=cfg)
    base = RangeQuery().sticky_key()
    easy = ds.random_rects(8, 1e-8, (0, 0, 1, 1), seed=5,
                           centers=(x, y))
    ex.run(RangeQuery(), easy, strict=True)
    assert ex._sticky[base] == (2, 2)
    ex.run(RangeQuery(), q["rects"])             # overflows the tier
    while ex.maintain():                          # escalate until clean
        ex.run(RangeQuery(), q["rects"])
    peak = ex._sticky[base]
    assert peak != (2, 2)
    moved = {}
    for _ in range(10):                           # easy traffic again
        ex.run(RangeQuery(), easy)
        moved = ex.maintain()
        if moved:
            break
    assert moved == {base: ex._sticky[base]}
    assert ex._sticky[base] < peak
    # counts stay exact across the demotion (fused fallback covers it)
    cnt, _, _ = ex.run(RangeQuery(), q["rects"])
    want = [len(s) for s in _exact_rect_sets(x, y, q["rects"])]
    assert np.asarray(cnt).tolist() == want


def test_demotion_ping_pong_backs_off(inputs):
    """A demotion the very next overflow undoes must DOUBLE the clean
    streak required before the next demotion attempt (exponential
    backoff) — steady serving cannot churn compiles, but downward
    re-tuning is never disabled for good."""
    from repro.core import EngineConfig, Executor, RangeQuery
    from repro.data import spatial as ds
    x, y, index, q = inputs
    cfg = EngineConfig(range_cap=2, range_cand=2, demote_after=2)
    ex = Executor(index, config=cfg)
    base = RangeQuery().sticky_key()
    easy = ds.random_rects(8, 1e-8, (0, 0, 1, 1), seed=5,
                           centers=(x, y))
    ex.run(RangeQuery(), easy, strict=True)
    ex.run(RangeQuery(), q["rects"])
    while ex.maintain():                          # escalate until clean
        ex.run(RangeQuery(), q["rects"])
    peak = ex._sticky[base]
    demoted = {}
    for _ in range(5):                            # easy traffic demotes
        ex.run(RangeQuery(), easy)
        demoted = ex.maintain()
        if demoted:
            break
    assert demoted and ex._sticky[base] < peak
    # demotion retraces the escalation ladder: re-escalating from the
    # demoted tier lands exactly on the warm peak executable
    assert ex._escalators[base](*ex._sticky[base]) == peak
    ex.run(RangeQuery(), q["rects"])              # bounces straight back
    assert ex.maintain() == {base: peak}
    assert ex._demote_backoff[base] == 2
    for _ in range(2 * cfg.demote_after - 1):     # doubled streak req
        ex.run(RangeQuery(), easy)
        assert ex.maintain() == {}                # rate-limited
        assert ex._sticky[base] == peak
    ex.run(RangeQuery(), easy)
    assert ex.maintain()                          # backoff elapsed:
    assert ex._sticky[base] < peak                # demotion recovers


# -- helper micro-equivalence (bitwise vs the argsort forms) -------------

def _ref_top_candidates(flags, c):
    import jax.numpy as jnp
    p = flags.shape[1]
    c = min(c, p)
    order = jnp.argsort(~flags, axis=1, stable=True)[:, :c]
    valid = jnp.take_along_axis(flags, order, axis=1)
    within = jnp.sum(flags.astype(jnp.int32), axis=1) <= c
    return np.asarray(order), np.asarray(valid), np.asarray(within)


def _ref_keep_window(vids, cnt, cap):
    import jax.numpy as jnp
    order = jnp.argsort(-(vids >= 0).astype(jnp.int32), axis=1,
                        stable=True)
    keep = min(vids.shape[1], max(cap * 8, 256))
    kept = jnp.take_along_axis(vids, order[:, :keep], axis=1)
    cap_ok = jnp.sum((kept >= 0).astype(jnp.int32), axis=1) == cnt
    return np.asarray(kept), np.asarray(cap_ok)


@pytest.mark.parametrize("c", [1, 3, 8, 64])
def test_top_candidates_matches_argsort(c):
    import jax.numpy as jnp
    from repro.core.local_ops import _top_candidates
    rng = np.random.default_rng(c)
    flags = jnp.asarray(rng.random((17, 23)) < 0.3)
    got = [np.asarray(a) for a in _top_candidates(flags, c)]
    want = _ref_top_candidates(flags, c)
    for g, w in zip(got, want):
        assert (g == w).all()


@pytest.mark.parametrize("cap,density", [(4, 0.02), (4, 0.9), (32, 0.5),
                                         (32, 0.0)])
def test_keep_window_matches_argsort(cap, density):
    """Includes overflow rows (density high enough that valid > keep)
    and the all-empty row."""
    import jax.numpy as jnp
    from repro.core.local_ops import _keep_window
    rng = np.random.default_rng(int(cap * 100 + density * 10))
    w = 1500
    vids = np.where(rng.random((9, w)) < density,
                    rng.integers(0, 10 ** 6, (9, w)), -1).astype(np.int32)
    cnt = jnp.asarray((vids >= 0).sum(axis=1), jnp.int32)
    vids = jnp.asarray(vids)
    gk, gok = _keep_window(vids, cnt, cap)
    wk, wok = _ref_keep_window(vids, cnt, cap)
    assert (np.asarray(gk) == wk).all()
    assert (np.asarray(gok) == wok).all()
