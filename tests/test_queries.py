import numpy as np
import pytest

from conftest import range_oracle
from repro.core import SpatialEngine, build_index, fit
from repro.data import spatial as ds


@pytest.fixture(scope="module")
def engine(built_index):
    x, y, part, idx = built_index
    return x, y, part, SpatialEngine(idx)


def test_point_query_exact(engine):
    x, y, part, eng = engine
    rng = np.random.default_rng(0)
    qx = np.concatenate([x[:40], rng.random(40).astype(np.float32) * 2])
    qy = np.concatenate([y[:40], rng.random(40).astype(np.float32) * 2])
    found = np.asarray(eng.point_query(qx, qy))
    truth = np.array([np.any((x == a) & (y == b))
                      for a, b in zip(qx, qy)])
    assert (found == truth).all()


@pytest.mark.parametrize("sel", [1e-5, 1e-3, 1e-1])
def test_range_count_exact(engine, sel):
    x, y, part, eng = engine
    rects = ds.random_rects(24, sel, part.bounds, seed=int(sel * 1e6),
                            centers=(x, y))
    got = np.asarray(eng.range_count(rects))
    assert (got == range_oracle(x, y, rects)).all()


def test_range_query_window_materializes(engine):
    x, y, part, eng = engine
    rects = ds.random_rects(16, 1e-4, part.bounds, seed=5,
                            centers=(x, y))
    cnt, vids, ok = eng.range_query(rects)
    assert bool(np.asarray(ok).all())
    want = range_oracle(x, y, rects)
    assert (np.asarray(cnt) == want).all()
    # materialized ids must be the actual in-rect points
    vids = np.asarray(vids)
    for i, r in enumerate(rects):
        got_ids = set(vids[i][vids[i] >= 0])
        truth = set(np.where((x >= r[0]) & (x <= r[2]) &
                             (y >= r[1]) & (y <= r[3]))[0])
        assert got_ids == truth


def test_empty_and_full_ranges(engine):
    x, y, part, eng = engine
    b = part.bounds
    rects = np.asarray([
        [2.0, 2.0, 3.0, 3.0],                 # fully outside
        [b[0], b[1], b[2], b[3]],             # everything
    ], np.float32)
    got = np.asarray(eng.range_count(rects))
    assert got[0] == 0
    assert got[1] == len(x)


def test_circle_count(engine):
    x, y, part, eng = engine
    rng = np.random.default_rng(2)
    ix = rng.integers(0, len(x), 12)
    cx, cy = x[ix], y[ix]
    r = np.full(12, 0.05, np.float32)
    got = np.asarray(eng.circle_count(cx, cy, r))
    truth = np.array([np.sum((x - a) ** 2 + (y - b) ** 2 <= 0.05 ** 2)
                      for a, b in zip(cx, cy)])
    assert (got == truth).all()


@pytest.mark.parametrize("kind", ["fixed", "adaptive", "quadtree",
                                  "rtree"])
def test_all_partitioners_give_exact_ranges(small_spatial, kind):
    x, y = small_spatial
    part = fit(kind, x, y, 10, seed=4)
    eng = SpatialEngine(build_index(x, y, part))
    rects = ds.random_rects(12, 1e-3, part.bounds, seed=8,
                            centers=(x, y))
    got = np.asarray(eng.range_count(rects))
    assert (got == range_oracle(x, y, rects)).all()
