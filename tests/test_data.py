import numpy as np
import pytest

from repro.configs import get_config
from repro.data import spatial as ds
from repro.data.tokens import TokenPipeline, input_specs, make_batch


def test_pipeline_deterministic_and_skippable():
    cfg = get_config("qwen2.5-3b", smoke=True)
    p1 = TokenPipeline(cfg, 2, 16, seed=3)
    batches = [np.asarray(next(p1)["tokens"]) for _ in range(5)]
    p2 = TokenPipeline(cfg, 2, 16, seed=3)
    p2.skip_to(3)
    assert (np.asarray(next(p2)["tokens"]) == batches[3]).all()


def test_input_specs_match_batches():
    import jax
    for arch in ["qwen2.5-3b", "seamless-m4t-medium",
                 "phi-3-vision-4.2b"]:
        cfg = get_config(arch, smoke=True)
        b = make_batch(cfg, 2, 64, seed=0)
        s = input_specs(cfg, 2, 64)
        assert set(b.keys()) == set(s.keys()), arch
        for k in b:
            assert tuple(b[k].shape) == tuple(s[k].shape), (arch, k)
            assert b[k].dtype == s[k].dtype, (arch, k)


@pytest.mark.parametrize("kind", ["uniform", "gaussian", "taxi"])
def test_spatial_generators(kind):
    x, y = ds.make(kind, 5000, seed=1)
    assert len(x) == 5000 and x.dtype == np.float32
    assert 0 <= x.min() and x.max() <= 1
    x2, y2 = ds.make(kind, 5000, seed=1)
    assert (x == x2).all()


def test_rect_selectivity():
    rects = ds.random_rects(100, 0.01, (0, 0, 1, 1), seed=0)
    areas = (rects[:, 2] - rects[:, 0]) * (rects[:, 3] - rects[:, 1])
    assert np.allclose(areas, 0.01, rtol=1e-4)


def test_polygons_valid():
    polys, ne = ds.random_polygons(20, (0, 0, 1, 1), seed=2)
    assert (ne >= 3).all() and (ne <= 12).all()
