import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint,
                              restore_or_init, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    proto = jax.eval_shape(lambda: _tree())
    got = load_checkpoint(str(tmp_path), 7, proto)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(got)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_partial_writes(tmp_path):
    save_checkpoint(str(tmp_path), 5, _tree())
    # simulate a crash mid-write: tmp dir + corrupt manifest
    bad = tmp_path / "step_00000009.tmp-123"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    half = tmp_path / "step_00000010"
    half.mkdir()
    (half / "arrays.npz").write_bytes(b"garbage")  # no manifest
    assert latest_step(str(tmp_path)) == 5


def test_restore_or_init_fresh_and_resume(tmp_path):
    tree, step = restore_or_init(str(tmp_path), _tree)
    assert step == 0
    save_checkpoint(str(tmp_path), 3, _tree(1))
    save_checkpoint(str(tmp_path), 6, _tree(2))
    tree, step = restore_or_init(str(tmp_path), _tree)
    assert step == 6
    want = jax.tree_util.tree_leaves(_tree(2))
    got = jax.tree_util.tree_leaves(tree)
    for a, b in zip(want, got):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_async_checkpoint_joins(tmp_path):
    h = save_checkpoint(str(tmp_path), 2, _tree(), async_write=True)
    h.join()
    assert latest_step(str(tmp_path)) == 2


def test_shape_mismatch_detected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad_proto = {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32),
                 "nested": {"b": jax.ShapeDtypeStruct((5,), jnp.int32),
                            "c": jax.ShapeDtypeStruct((), jnp.float32)}}
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), 1, bad_proto)


def test_elastic_resharding_device_put(tmp_path):
    """Load a checkpoint under a (trivially different) sharding — the
    elastic path: arrays are stored unsharded and re-placed on load."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    save_checkpoint(str(tmp_path), 4, t)
    mesh = make_host_mesh()
    shard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    proto = jax.eval_shape(lambda: _tree())
    got = load_checkpoint(str(tmp_path), 4, proto, sharding_tree=shard)
    assert got["a"].sharding.is_equivalent_to(
        NamedSharding(mesh, P()), 2)
