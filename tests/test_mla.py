"""MLA: absorbed-form decode must equal expanded-form attention."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models.common import ModelConfig, init_params


def test_absorbed_decode_equals_expanded():
    cfg = ModelConfig(
        name="mla-test", vocab=64, d_model=32, n_layers=1, n_heads=4,
        n_kv_heads=4, attn="mla", q_lora=0, kv_lora=16, qk_nope_dim=8,
        qk_rope_dim=4, v_head_dim=8, d_ff=64, compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = params["layers"]
    p = jax.tree_util.tree_map(lambda a: a[0], p)["attn"]
    rng = np.random.default_rng(0)
    t = 9
    x = jnp.asarray(rng.standard_normal((2, t, 32)), jnp.float32)
    positions = jnp.arange(t, dtype=jnp.int32)
    out_full, (c, krope) = A.mla_attn(p, x, cfg, positions=positions)

    # decode the last token against the cache of the first t-1
    cache_c = jnp.zeros((2, t, cfg.kv_lora), jnp.float32
                        ).at[:, : t - 1].set(c[:, : t - 1])
    cache_r = jnp.zeros((2, t, cfg.qk_rope_dim), jnp.float32
                        ).at[:, : t - 1].set(krope[:, : t - 1])
    out_dec, _ = A.mla_decode(p, x[:, t - 1:], cfg, cache_c=cache_c,
                              cache_rope=cache_r,
                              pos=jnp.int32(t - 1))
    diff = float(jnp.max(jnp.abs(out_dec[:, 0] - out_full[:, -1])))
    assert diff < 1e-4, diff


def test_mla_cache_is_compressed():
    """The MLA decode cache must be r+rope floats per token — much
    smaller than the 2*H*D GQA equivalent (paper: the reason deepseek
    serves long contexts)."""
    cfg = ModelConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                      attn="mla", kv_lora=512, qk_rope_dim=64)
    mla_per_tok = cfg.kv_lora + cfg.qk_rope_dim
    gqa_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    assert mla_per_tok * 7 < gqa_per_tok
