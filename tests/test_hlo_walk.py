"""Trip-count-aware HLO cost walker (the roofline backbone)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_walk


def test_scan_trip_count_multiplies_flops():
    def scanned(x, ws):
        def b(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(b, x, ws)
        return y

    x = jnp.zeros((128, 128), jnp.float32)
    ws = jnp.zeros((7, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    t = hlo_walk.total_cost(c.as_text())
    assert abs(t["flops"] - 2 * 7 * 128 ** 3) < 1
    # XLA's own analysis undercounts (documents why the walker exists);
    # jax returns it as a list-of-dicts or a dict depending on version
    assert hlo_walk.xla_cost_analysis(c)["flops"] < t["flops"]


def test_nested_scan():
    def nested(x, ws):
        def outer(c, _):
            def b(cc, w):
                return cc @ w, None
            y, _ = jax.lax.scan(b, c, ws)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((5, 64, 64), jnp.float32)
    c = jax.jit(nested).lower(x, ws).compile()
    t = hlo_walk.total_cost(c.as_text())
    assert abs(t["flops"] - 3 * 5 * 2 * 64 ** 3) < 1


def test_plain_dot_flops_and_bytes():
    a = jnp.zeros((64, 32), jnp.bfloat16)
    b = jnp.zeros((32, 16), jnp.bfloat16)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    t = hlo_walk.total_cost(c.as_text())
    assert abs(t["flops"] - 2 * 64 * 32 * 16) < 1
    want_bytes = (64 * 32 + 32 * 16 + 64 * 16) * 2
    assert t["hbm_bytes"] >= want_bytes
    # CPU XLA upcasts bf16 operands to f32 (convert ops add ~3x) —
    # bound the model at ~8x the minimal traffic
    assert t["hbm_bytes"] <= want_bytes * 8


def test_dus_counts_update_not_buffer():
    """Loop cache-update DUS must cost ~slice bytes per iteration, not
    the whole buffer per iteration (in-place aliasing)."""
    buf = jnp.zeros((1024, 1024), jnp.float32)
    upd = jnp.zeros((1, 1024), jnp.float32)

    def f(buf, upd):
        def body(i, b):
            return jax.lax.dynamic_update_slice(b, upd, (i, 0))
        return jax.lax.fori_loop(0, 64, body, buf)

    c = jax.jit(f).lower(buf, upd).compile()
    t = hlo_walk.total_cost(c.as_text())
    # naive (no aliasing) would be 64 * 2 * 4MB = 512MB
    assert t["hbm_bytes"] < 3 * 1024 * 1024 * 4


def test_shape_parsing():
    assert hlo_walk._shapes_bytes("f32[8,4]{1,0}") == 128
    assert hlo_walk._shapes_bytes("(bf16[2,2], s32[3])") == 20
    assert hlo_walk._shapes_bytes("pred[]") == 1
