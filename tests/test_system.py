"""End-to-end behaviour tests: the paper's full pipeline on one node.

Generate city-scale-ish data -> partition -> build the learned index ->
serve a mixed query workload -> verify every result against oracles, and
check the paper's qualitative claims (build scaling; learned interval <<
partition size; index survives checkpoint/restart).
"""
import time

import numpy as np
import pytest

from conftest import knn_oracle, pip_oracle, range_oracle
from repro.core import SpatialEngine, build_index, fit
from repro.core import queries as Q
from repro.core import keys as K
from repro.data import spatial as ds


@pytest.fixture(scope="module")
def system():
    x, y = ds.make("taxi", 50000, seed=13)
    part = fit("kdtree", x, y, 32, seed=1)
    idx = build_index(x, y, part)
    return x, y, part, idx, SpatialEngine(idx)


def test_mixed_workload_end_to_end(system):
    x, y, part, idx, eng = system
    rng = np.random.default_rng(5)
    # point
    ix = rng.integers(0, len(x), 32)
    found = np.asarray(eng.point_query(x[ix], y[ix]))
    assert found.all()
    # range
    rects = ds.random_rects(16, 1e-4, part.bounds, seed=17,
                            centers=(x, y))
    assert (np.asarray(eng.range_count(rects)) ==
            range_oracle(x, y, rects)).all()
    # kNN (paper default k=10)
    d2, _ = eng.knn(x[ix[:8]], y[ix[:8]], 10)
    want = knn_oracle(x, y, x[ix[:8]], y[ix[:8]], 10)
    assert np.allclose(np.sort(np.asarray(d2), 1), want, rtol=1e-5)
    # join
    polys, ne = ds.random_polygons(6, part.bounds, seed=19)
    got = np.asarray(eng.join_count(polys, ne))
    want_j = np.array([pip_oracle(x, y, polys[i], ne[i]).sum()
                       for i in range(6)])
    assert (got == want_j).all()


def test_learned_interval_much_smaller_than_partition(system):
    """The spline bounds restrict the scan to a tiny interval — the
    mechanism behind the paper's 2-3 orders-of-magnitude query claim."""
    x, y, part, idx, eng = system
    rects = ds.random_rects(32, 1e-5, part.bounds, seed=23,
                            centers=(x, y))
    klo, khi = K.rect_key_range(rects, idx.key_spec)
    klo = K.keys_to_f32(klo)
    khi = K.keys_to_f32(khi)
    parts = eng.parts
    widths = []
    for p in range(idx.num_partitions):
        part_p = {k: v[p] for k, v in parts.items()}
        s, e = Q.learned_bounds(part_p, klo, khi,
                                radix_bits=idx.radix_bits,
                                probe=idx.probe)
        widths.append(np.asarray(e - s))
    # average learned interval across candidate partitions
    w = np.mean(np.concatenate(widths))
    assert w < 0.02 * idx.n_pad, (w, idx.n_pad)


def test_build_scales_subquadratically(system):
    """Index build is one sort + one linear pass; doubling N must not
    quadruple the WORK (sanity check on the O(N log N + N) claim).

    Measured as best-of CPU time (``time.process_time`` sums actual
    compute across threads) rather than wall clock: on a loaded CI
    runner wall-clock stalls from unrelated processes used to trip the
    old 6x threshold even though the build did no extra work."""
    import jax
    x, y = ds.make("uniform", 20000, seed=3)
    part = fit("kdtree", x, y, 8, seed=1)

    def best_of(n, f):
        ts = []
        for _ in range(n):
            t0 = time.process_time()
            jax.block_until_ready(f())
            ts.append(time.process_time() - t0)
        return min(ts)

    jax.block_until_ready(build_index(x, y, part).key)  # warm caches
    t1 = best_of(5, lambda: build_index(x, y, part).key)
    x2, y2 = ds.make("uniform", 40000, seed=3)
    part2 = fit("kdtree", x2, y2, 8, seed=1)
    jax.block_until_ready(build_index(x2, y2, part2).key)
    t2 = best_of(5, lambda: build_index(x2, y2, part2).key)
    # 2x the rows: O(N log N) predicts ~2.1x work; quadratic would be
    # 4x. 3.2x splits those while tolerating constant-overhead noise.
    assert t2 < 3.2 * max(t1, 1e-3), (t1, t2)


def test_index_serializes_through_checkpoint(system, tmp_path):
    """The learned index is a pytree: the checkpoint layer persists it
    (serving restart path)."""
    import dataclasses
    import jax
    from repro.checkpoint import load_checkpoint, save_checkpoint
    x, y, part, idx, eng = system
    arrays = {f.name: getattr(idx, f.name)
              for f in dataclasses.fields(idx)
              if not f.metadata.get("static")
              and getattr(idx, f.name) is not None}
    save_checkpoint(str(tmp_path), 1, arrays)
    proto = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), arrays)
    got = load_checkpoint(str(tmp_path), 1, proto)
    assert (np.asarray(got["key"]) == np.asarray(idx.key)).all()
