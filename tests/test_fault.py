"""Fault tolerance: crash -> restart resumes bit-exact; watchdog."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.train import TrainLoopConfig, make_train_step, train_loop
from repro.train.loop import InjectedCrash


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen2.5-3b", smoke=True)
    model = build_model(cfg)
    step = make_train_step(model, peak_lr=1e-3, warmup=2, total_steps=20,
                           donate=False)
    return cfg, model, step


def test_crash_and_resume_bit_exact(tiny, tmp_path):
    cfg, model, step = tiny
    ckpt = str(tmp_path / "ck")

    def run(steps, crash_at=None):
        pipe = TokenPipeline(cfg, 2, 32, seed=0)
        lc = TrainLoopConfig(steps=steps, ckpt_every=4, ckpt_dir=ckpt,
                             log_every=0, crash_at_step=crash_at,
                             async_ckpt=False)
        return train_loop(model, step, pipe, lc,
                          rng=jax.random.PRNGKey(0),
                          log_fn=lambda *_: None)

    # uninterrupted reference
    ref_params, _, ref_hist = run(12)
    ref_losses = ref_hist["loss"]

    # crashed + resumed run (fresh ckpt dir)
    import shutil
    shutil.rmtree(ckpt, ignore_errors=True)
    with pytest.raises(InjectedCrash):
        run(12, crash_at=8)
    params2, _, hist2 = run(12)   # auto-resume from step 8
    assert len(hist2["loss"]) == 4   # steps 8..11 only
    assert np.allclose(hist2["loss"], ref_losses[8:], atol=1e-5), \
        "resumed losses diverge from uninterrupted run"
    for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                    jax.tree_util.tree_leaves(params2)):
        assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class _RepeatPipeline(TokenPipeline):
    """Same batch every step: loss must drop as the model memorizes."""

    def __next__(self):
        from repro.data.tokens import make_batch
        return make_batch(self.cfg, self.batch, self.seq, self.seed, 0)


def test_loss_decreases(tiny, tmp_path):
    cfg, model, step = tiny
    pipe = _RepeatPipeline(cfg, 2, 32, seed=0)
    lc = TrainLoopConfig(steps=30, ckpt_dir=None, log_every=0)
    _, _, hist = train_loop(model, step, pipe, lc,
                            rng=jax.random.PRNGKey(1),
                            log_fn=lambda *_: None)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.5, (first, last)


def test_watchdog_counts_stragglers(tiny, monkeypatch):
    cfg, model, step = tiny
    import repro.train.loop as L
    times = iter([0.0, 0.1,    # step 0: 100ms
                  1.0, 1.1,    # step 1: 100ms
                  2.0, 2.1,    # step 2
                  3.0, 4.9])   # step 3: 1.9s -> straggler
    monkeypatch.setattr(L.time, "perf_counter", lambda: next(times))
    pipe = TokenPipeline(cfg, 2, 32, seed=0)
    lc = TrainLoopConfig(steps=4, ckpt_dir=None, log_every=0,
                         straggler_factor=3.0)
    _, _, hist = train_loop(model, step, pipe, lc,
                            rng=jax.random.PRNGKey(0),
                            log_fn=lambda *_: None)
    assert hist["stragglers"] == 1
