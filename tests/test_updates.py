"""Mutable learned index (DESIGN.md §11): epoch-versioned updates.

Property/parity contract:

  - BETWEEN updates and re-fit, every query stays EXACT: counts
    bitwise-equal a fresh ``build_index`` on the equivalent point set,
    materialized id sets exactly equal, kNN distances bitwise-equal.
  - AFTER ``refit_partitions`` of the touched partitions, every query
    spec (point / range / circle / kNN / join, strict and fused, both
    kernel backends, sharded and unsharded) is BITWISE-identical to the
    fresh build — the re-fit compacts each touched row into exactly the
    layout the build pipeline would produce (``build_index(vid=...)``
    pins the id assignment).
  - A batched update touching k of P partitions re-fits only those k
    (epoch / refit_gen counters), re-verifying the spline error bound
    per touched partition.
  - Capacity growth (delta buffer) bumps ``shape_epoch`` and evicts
    executables compiled against superseded shapes; ordinary updates
    leave the executable cache intact (update programs cache like
    queries, keyed by their epoch-invariant shapes).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (CircleQuery, DeleteBatch, EngineConfig, Executor,
                        InsertBatch, Knn, PointQuery, RangeCount,
                        RangeQuery, Refit, SpatialJoin, build_index, fit,
                        verify_eps)
from repro.data import spatial as ds

N = 6000
N_INS = 400
N_DEL = 200


@pytest.fixture(scope="module")
def mutated():
    """One interleaving of insert/delete applied through the executor,
    plus the equivalent point set (original - deleted + surviving
    inserts, in vid order) for fresh-rebuild comparison."""
    x, y = ds.make("gaussian", N, seed=7)
    part = fit("kdtree", x, y, 8, seed=0)
    ex = Executor(build_index(x, y, part))

    rng = np.random.default_rng(3)
    ins_x, ins_y = ds.make("gaussian", N_INS, seed=11)
    vids = ex.run(InsertBatch(), ins_x, ins_y)
    assert vids.tolist() == list(range(N, N + N_INS))

    del_ix = rng.choice(N, N_DEL, replace=False)
    # delete originals AND a slice of the still-buffered inserts
    removed = ex.run(DeleteBatch(),
                     np.concatenate([x[del_ix], ins_x[:50]]),
                     np.concatenate([y[del_ix], ins_y[:50]]))
    assert removed == N_DEL + 50

    keep = np.ones(N, bool)
    keep[del_ix] = False
    ax = np.concatenate([x[keep], ins_x[50:]])
    ay = np.concatenate([y[keep], ins_y[50:]])
    avid = np.concatenate([np.arange(N)[keep],
                           np.arange(N + 50, N + N_INS)])
    return dict(x=x, y=y, part=part, ex=ex, ax=ax, ay=ay, avid=avid,
                ins=(ins_x, ins_y), deleted=(x[del_ix], y[del_ix]))


def _queries(part, x, y, qn=12, seed=5):
    rng = np.random.default_rng(seed)
    ix = rng.integers(0, len(x), qn)
    qx, qy = x[ix], y[ix]
    rects = ds.random_rects(qn, 1e-3, part.bounds, seed=seed,
                            centers=(x, y))
    polys, ne = ds.random_polygons(6, part.bounds, seed=seed + 1)
    r = np.full(qn, 0.03, np.float32)
    return qx, qy, rects, polys, ne, r


def _spec_sweep(qx, qy, rects, polys, ne, r, k=7):
    return [
        ("point", PointQuery(), (qx, qy)),
        ("range_count", RangeCount(), (rects,)),
        ("range", RangeQuery(), (rects,)),
        ("circle", CircleQuery(), (qx, qy, r)),
        ("circle_mat", CircleQuery(materialize=True), (qx, qy, r)),
        ("knn", Knn(k=k), (qx, qy)),
        ("knn_exact", Knn(k=k, mode="exact"), (qx, qy)),
        ("join", SpatialJoin(), (polys, ne)),
    ]


def _assert_bitwise(got, want, ctx):
    gl = got if isinstance(got, tuple) else (got,)
    wl = want if isinstance(want, tuple) else (want,)
    for a, b in zip(gl, wl):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape, (ctx, a.shape, b.shape)
        assert (a == b).all(), (ctx, a, b)


# -- pre-refit: delta-aware scans stay exact ------------------------------

def test_prerefit_counts_and_sets_match_fresh_build(mutated):
    m = mutated
    ex = m["ex"]
    fresh = Executor(build_index(m["ax"], m["ay"], m["part"],
                                 vid=m["avid"], n_pad=ex.index.n_pad))
    qx, qy, rects, polys, ne, r = _queries(m["part"], m["x"], m["y"])

    _assert_bitwise(ex.run(RangeCount(), rects),
                    fresh.run(RangeCount(), rects), "range_count")
    _assert_bitwise(ex.run(CircleQuery(), qx, qy, r, strict=True),
                    fresh.run(CircleQuery(), qx, qy, r, strict=True),
                    "circle")
    _assert_bitwise(ex.run(SpatialJoin(), polys, ne, strict=True),
                    fresh.run(SpatialJoin(), polys, ne, strict=True),
                    "join")
    # kNN: the k smallest distances are a unique multiset -> bitwise
    gd2, _ = ex.run(Knn(k=7), qx, qy, strict=True)
    wd2, _ = fresh.run(Knn(k=7), qx, qy, strict=True)
    _assert_bitwise(gd2, wd2, "knn d2")

    # membership: live inserts found, deleted points gone
    ins_x, ins_y = m["ins"]
    dx, dy = m["deleted"]
    got = np.asarray(ex.run(PointQuery(),
                            np.concatenate([ins_x[50:60], ins_x[:10],
                                            dx[:10]]),
                            np.concatenate([ins_y[50:60], ins_y[:10],
                                            dy[:10]])))
    assert got[:10].all()                # live buffered inserts
    assert not got[10:].any()            # deleted inserts + originals

    # materialized ranges: exact counts, exact id sets
    gcnt, gvids, gok = ex.run(RangeQuery(), rects, strict=True)
    wcnt, wvids, wok = fresh.run(RangeQuery(), rects, strict=True)
    assert (np.asarray(gcnt) == np.asarray(wcnt)).all()
    assert bool(np.asarray(gok).all()) and bool(np.asarray(wok).all())
    for i in range(len(rects)):
        a = {v for v in np.asarray(gvids)[i] if v >= 0}
        b = {v for v in np.asarray(wvids)[i] if v >= 0}
        assert a == b, i


# -- refit: targeted, counted, eps-verified -------------------------------

def test_refit_touches_only_touched_partitions(mutated):
    m = mutated
    ex = m["ex"]
    idx = ex.index
    dirty = [int(p) for p in np.nonzero(
        (np.asarray(idx.delta_count) > 0) | (np.asarray(idx.dead) > 0))[0]]
    assert len(dirty) >= 2
    k = dirty[: len(dirty) // 2]
    rest = [p for p in dirty if p not in k]
    gen0 = np.asarray(idx.refit_gen).copy()
    knots0 = np.asarray(idx.knot_keys).copy()
    epoch0 = idx.epoch

    touched = ex.refit(k)
    assert sorted(touched) == sorted(k)
    idx = ex.index
    gen1 = np.asarray(idx.refit_gen)
    assert (gen1[k] == gen0[k] + 1).all()
    untouched = [p for p in range(idx.num_partitions) if p not in k]
    assert (gen1[untouched] == gen0[untouched]).all()
    # untouched partitions' learned model is preserved bitwise
    assert (np.asarray(idx.knot_keys)[untouched] ==
            knots0[untouched]).all()
    assert idx.epoch == epoch0 + 1
    # touched rows are clean now
    assert (np.asarray(idx.delta_count)[k] == 0).all()
    assert (np.asarray(idx.dead)[k] == 0).all()

    # eps bound re-verified per touched partition: the re-fit spline
    # honors the corridor's 2*eps interpolation bound (the same bound a
    # fresh build exhibits; see mutate.verify_eps)
    for p in touched:
        err = verify_eps(idx, p)
        assert err <= 2 * idx.eps + 1, (p, err)

    # finish compaction for the downstream parity tests
    ex.refit(rest)
    assert (np.asarray(ex.index.refit_gen)[rest] == gen0[rest] + 1).all()


# -- post-refit: bitwise parity, every spec, both modes -------------------

def test_postrefit_bitwise_parity_all_specs(mutated):
    m = mutated
    ex = m["ex"]
    ex.refit()        # idempotent if the previous test already ran
    fresh = Executor(build_index(m["ax"], m["ay"], m["part"],
                                 vid=m["avid"], n_pad=ex.index.n_pad))
    qx, qy, rects, polys, ne, r = _queries(m["part"], m["x"], m["y"])
    for name, spec, args in _spec_sweep(qx, qy, rects, polys, ne, r):
        for strict in (True, False):
            _assert_bitwise(ex.run(spec, *args, strict=strict),
                            fresh.run(spec, *args, strict=strict),
                            (name, strict))


@pytest.mark.parametrize("backend", ["pallas"])
def test_postrefit_parity_pallas_backend(backend):
    """Reduced sweep on the pallas (interpret-mode) backend: the delta
    probes and tombstone poisoning must be kernel-transparent."""
    x, y = ds.make("gaussian", 2500, seed=9)
    part = fit("kdtree", x, y, 4, seed=0)
    cfg = EngineConfig(backend=backend)
    ex = Executor(build_index(x, y, part), config=cfg)

    ins_x, ins_y = ds.make("gaussian", 120, seed=13)
    ex.run(InsertBatch(), ins_x, ins_y)
    rng = np.random.default_rng(5)
    del_ix = rng.choice(2500, 80, replace=False)
    ex.run(DeleteBatch(), x[del_ix], y[del_ix])

    keep = np.ones(2500, bool)
    keep[del_ix] = False
    ax = np.concatenate([x[keep], ins_x])
    ay = np.concatenate([y[keep], ins_y])
    avid = np.concatenate([np.arange(2500)[keep],
                           np.arange(2500, 2620)])
    qx, qy, rects, polys, ne, r = _queries(part, x, y, qn=6, seed=17)

    # pre-refit: exact counts through the kernel scan stages
    fresh_pre = Executor(build_index(ax, ay, part, vid=avid,
                                     n_pad=ex.index.n_pad), config=cfg)
    _assert_bitwise(ex.run(RangeCount(), rects),
                    fresh_pre.run(RangeCount(), rects), "pallas rc")
    _assert_bitwise(ex.run(CircleQuery(), qx, qy, r, strict=True),
                    fresh_pre.run(CircleQuery(), qx, qy, r, strict=True),
                    "pallas circle")
    gd2, _ = ex.run(Knn(k=5), qx, qy, strict=True)
    wd2, _ = fresh_pre.run(Knn(k=5), qx, qy, strict=True)
    _assert_bitwise(gd2, wd2, "pallas knn")

    # post-refit: bitwise on a representative subset
    ex.refit()
    fresh = Executor(build_index(ax, ay, part, vid=avid,
                                 n_pad=ex.index.n_pad), config=cfg)
    for name, spec, args in [
            ("point", PointQuery(), (qx, qy)),
            ("range_count", RangeCount(), (rects,)),
            ("range", RangeQuery(), (rects,)),
            ("circle", CircleQuery(), (qx, qy, r)),
            ("knn", Knn(k=5), (qx, qy))]:
        _assert_bitwise(ex.run(spec, *args, strict=True),
                        fresh.run(spec, *args, strict=True),
                        ("pallas", name))


# -- executable-cache semantics across updates ----------------------------

def test_update_executables_cache_like_queries():
    x, y = ds.make("gaussian", 3000, seed=21)
    part = fit("kdtree", x, y, 4, seed=0)
    ex = Executor(build_index(x, y, part, delta_cap=512))
    b1x, b1y = ds.make("gaussian", 64, seed=22)
    b2x, b2y = ds.make("gaussian", 64, seed=23)
    ex.run(InsertBatch(), b1x, b1y)
    n0 = ex.stats()["cache_size"]
    keys0 = set(ex.cache_keys())
    ex.run(InsertBatch(), b2x, b2y)    # same shapes: cached executable
    assert ex.stats()["cache_size"] == n0
    assert set(ex.cache_keys()) == keys0
    assert any(k[3] == "u" and k[2] == ("insert",)
               for k in ex.cache_keys())


def test_capacity_growth_bumps_shape_epoch_and_evicts_stale():
    x, y = ds.make("gaussian", 3000, seed=25)
    part = fit("kdtree", x, y, 4, seed=0)
    ex = Executor(build_index(x, y, part),
                  config=EngineConfig(delta_cap=64))
    rects = ds.random_rects(8, 1e-3, part.bounds, seed=26,
                            centers=(x, y))
    ex.run(RangeCount(), rects)        # warm a query executable
    se0 = ex.index.shape_epoch
    assert all(k[5] == se0 for k in ex.cache_keys())

    bx, by = ds.make("gaussian", 300, seed=27)
    ex.run(InsertBatch(), bx, by)      # overflows delta_cap=64 -> grow
    assert ex.index.shape_epoch > se0
    # the stale-epoch sweep leaves NO executable from the old shapes
    assert all(k[5] == ex.index.shape_epoch for k in ex.cache_keys())
    # and queries recompile + stay exact against a fresh build
    fresh = Executor(build_index(
        np.concatenate([x, bx]), np.concatenate([y, by]), part,
        n_pad=ex.index.n_pad))
    _assert_bitwise(ex.run(RangeCount(), rects),
                    fresh.run(RangeCount(), rects), "post-growth")


def test_epoch_counters_track_updates():
    x, y = ds.make("gaussian", 2000, seed=31)
    part = fit("kdtree", x, y, 4, seed=0)
    ex = Executor(build_index(x, y, part, delta_cap=128))
    assert ex.index.epoch == 0
    bx, by = ds.make("gaussian", 32, seed=32)
    ex.run(InsertBatch(), bx, by)
    assert ex.index.epoch == 1
    ex.run(DeleteBatch(), bx[:8], by[:8])
    assert ex.index.epoch == 2
    ex.run(Refit())
    assert ex.index.epoch == 3
    st = ex.stats()
    assert st["updates"] == 2 and st["refits"] == 1


def test_out_of_domain_inserts_visible_to_all_queries():
    """Inserts outside the build-time bounds land in the overflow grid;
    its box must widen so the global filter (range/circle/kNN candidate
    selection) can see them — not just the point probe."""
    x, y = ds.make("gaussian", 2000, seed=51)
    part = fit("kdtree", x, y, 4, seed=0)
    ex = Executor(build_index(x, y, part, delta_cap=64))
    ox = np.asarray([5.0, 5.1], np.float32)
    oy = np.asarray([5.0, 5.1], np.float32)
    ex.run(InsertBatch(), ox, oy)
    rect = np.asarray([[4.9, 4.9, 5.2, 5.2]], np.float32)
    assert np.asarray(ex.run(PointQuery(), ox, oy)).all()
    assert int(ex.run(RangeCount(), rect)[0]) == 2          # pre-refit
    cnt = ex.run(CircleQuery(), ox[:1], oy[:1],
                 np.asarray([0.5], np.float32), strict=True)
    assert int(np.asarray(cnt)[0]) == 2
    d2, vid = ex.run(Knn(k=2), ox[:1], oy[:1], strict=True)
    assert set(np.asarray(vid)[0]) == {2000, 2001}
    ex.refit()
    assert int(ex.run(RangeCount(), rect)[0]) == 2          # post-refit
    assert np.asarray(ex.run(PointQuery(), ox, oy)).all()


# -- serving path: occupancy-triggered deferred compaction ----------------

def test_serve_session_mutations_and_maintain_refit():
    from repro.serve.spatial import SpatialServeSession
    x, y = ds.make("gaussian", 2000, seed=41)
    part = fit("kdtree", x, y, 4, seed=0)
    sess = SpatialServeSession(
        build_index(x, y, part),
        config=EngineConfig(delta_cap=64, delta_occupancy=0.01))
    rects = ds.random_rects(6, 1e-3, part.bounds, seed=42,
                            centers=(x, y))
    sess.submit(RangeCount(), rects)
    bx, by = ds.make("gaussian", 100, seed=43)
    sess.insert(bx, by)
    # tiny occupancy threshold: the insert scheduled a deferred re-fit
    assert sess.stats()["pending_refit"]
    moved = sess.maintain()
    assert moved.get("refit")
    assert not sess.stats()["pending_refit"]
    assert sess.executor.refits == 1
    # post-compaction results bitwise match a fresh build
    fresh = Executor(build_index(
        np.concatenate([x, bx]), np.concatenate([y, by]), part,
        n_pad=sess.executor.index.n_pad))
    _assert_bitwise(sess.submit(RangeCount(), rects),
                    fresh.run(RangeCount(), rects), "serve")
    removed = sess.delete(bx[:5], by[:5])
    assert removed == 5


# -- sharded executors: updates + parity under a mesh ---------------------

SHARDED = r"""
import numpy as np, jax
from repro.core import *
from repro.data import spatial as ds

mesh = jax.make_mesh((2, 2), ("data", "query"))
x, y = ds.make("taxi", 8000, seed=2)
part = fit("kdtree", x, y, 8)
idx = build_index(x, y, part)

plain = Executor(idx)
qex = Executor(idx, mesh=mesh, part_axis="data", query_axis="query",
               config=EngineConfig(query_shard_threshold=16))

bx, by = ds.make("taxi", 200, seed=9)
for ex in (plain, qex):
    ex.run(InsertBatch(), bx, by)
    ex.run(DeleteBatch(), x[:100], y[:100])

rng = np.random.default_rng(0)
n_q = 42   # above threshold AND not a query-axis multiple (padding)
ix = rng.integers(0, len(x), n_q)
qx, qy = x[ix], y[ix]
rects = ds.random_rects(n_q, 1e-3, part.bounds, seed=3, centers=(x, y))

def check(tag):
    for spec, args in ((PointQuery(), (qx, qy)),
                       (RangeCount(), (rects,)),
                       (RangeQuery(), (rects,)),
                       (Knn(k=5), (qx, qy))):
        w = plain.run(spec, *args, strict=True)
        g = qex.run(spec, *args, strict=True)
        wl = w if isinstance(w, tuple) else (w,)
        gl = g if isinstance(g, tuple) else (g,)
        for a, b in zip(wl, gl):
            assert (np.asarray(a) == np.asarray(b)).all(), (tag, spec)

check("pre-refit")
assert [k for k in qex.cache_keys() if k[1]], "expected qshard variants"
for ex in (plain, qex):
    ex.refit()
check("post-refit")
print("OK")
"""


@pytest.mark.slow
def test_sharded_updates_match_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SHARDED], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
