import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import make_batch
from repro.models import build_model
from repro.serve import ServeSession, generate


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2.5-3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_greedy_generate_deterministic(qwen):
    cfg, model, params = qwen
    batch = make_batch(cfg, 2, 16, seed=1)
    b = {"tokens": batch["tokens"]}
    out1 = generate(model, params, b, steps=8)
    out2 = generate(model, params, b, steps=8)
    assert out1.shape == (2, 8)
    assert (np.asarray(out1) == np.asarray(out2)).all()


def test_generate_matches_stepwise_forward(qwen):
    """Greedy decode must equal greedy argmax over repeated fwd passes."""
    cfg, model, params = qwen
    toks = make_batch(cfg, 1, 8, seed=2)["tokens"]
    out = np.asarray(generate(model, params, {"tokens": toks}, steps=4))
    cur = np.asarray(toks)
    for i in range(4):
        logits = model.forward(params, {"tokens": jnp.asarray(cur)})[0]
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == out[0, i], f"token {i}"
        cur = np.concatenate([cur, [[nxt]]], axis=1)


def test_serve_session_steps(qwen):
    cfg, model, params = qwen
    sess = ServeSession(model, params, batch_size=4, max_len=32)
    tok = jnp.zeros((4, 1), jnp.int32)
    for _ in range(3):
        logits = sess.step(tok)
        assert logits.shape == (4, 1, cfg.vocab)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
            jnp.int32)
    assert int(sess.pos[0]) == 3


def test_rwkv_session_state_based():
    cfg = get_config("rwkv6-3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = ServeSession(model, params, batch_size=2, max_len=8)
    tok = jnp.ones((2, 1), jnp.int32)
    logits = sess.step(tok)
    assert logits.shape == (2, 1, cfg.vocab)
