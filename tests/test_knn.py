import numpy as np
import pytest

from conftest import knn_oracle
from repro.core import SpatialEngine


@pytest.fixture(scope="module")
def engine(built_index):
    x, y, part, idx = built_index
    return x, y, SpatialEngine(idx)


@pytest.mark.parametrize("k", [1, 5, 10, 32])
@pytest.mark.parametrize("mode", ["exact", "pruned"])
def test_knn_exactness(engine, k, mode):
    x, y, eng = engine
    rng = np.random.default_rng(k)
    ix = rng.integers(0, len(x), 16)
    qx, qy = x[ix], y[ix]
    d2, vid = eng.knn(qx, qy, k, mode=mode)
    got = np.sort(np.asarray(d2), axis=1)
    want = knn_oracle(x, y, qx, qy, k)
    assert np.allclose(got, want, rtol=1e-5, atol=1e-10)
    # returned ids actually achieve those distances
    vid = np.asarray(vid)
    for i in range(len(qx)):
        dd = (x[vid[i]] - qx[i]) ** 2 + (y[vid[i]] - qy[i]) ** 2
        assert np.allclose(np.sort(dd), want[i], rtol=1e-5, atol=1e-10)


def test_knn_far_query(engine):
    """Query far outside the data bounds must still be exact (radius
    expansion loop, paper Eq. 3 bound)."""
    x, y, eng = engine
    qx = np.asarray([5.0, -3.0], np.float32)
    qy = np.asarray([5.0, -3.0], np.float32)
    d2, _ = eng.knn(qx, qy, 3, mode="pruned")
    want = knn_oracle(x, y, qx, qy, 3)
    assert np.allclose(np.sort(np.asarray(d2), axis=1), want, rtol=1e-5)


def test_knn_duplicate_points(engine):
    x, y, eng = engine
    qx, qy = x[:4], y[:4]  # exact data points: d2[0] == 0
    d2, _ = eng.knn(qx, qy, 2)
    assert np.allclose(np.min(np.asarray(d2), axis=1), 0.0, atol=1e-12)
