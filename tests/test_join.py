import numpy as np

from conftest import pip_oracle
from repro.core import SpatialEngine
from repro.data import spatial as ds


def test_join_counts_exact(built_index):
    x, y, part, idx = built_index
    eng = SpatialEngine(idx)
    polys, ne = ds.random_polygons(12, part.bounds, seed=3)
    got = np.asarray(eng.join_count(polys, ne))
    want = np.array([pip_oracle(x, y, polys[i], ne[i]).sum()
                     for i in range(len(ne))])
    assert (got == want).all()


def test_join_degenerate_polygons(built_index):
    x, y, part, idx = built_index
    eng = SpatialEngine(idx)
    # triangle far outside data
    polys = np.zeros((2, 12, 2), np.float32)
    polys[0, :3] = [[5, 5], [6, 5], [5.5, 6]]
    # big square covering everything
    b = part.bounds
    polys[1, :4] = [[b[0] - 1, b[1] - 1], [b[2] + 1, b[1] - 1],
                    [b[2] + 1, b[3] + 1], [b[0] - 1, b[3] + 1]]
    ne = np.asarray([3, 4], np.int32)
    got = np.asarray(eng.join_count(polys, ne))
    assert got[0] == 0
    assert got[1] == len(x)


def test_join_concave_polygon(built_index):
    x, y, part, idx = built_index
    eng = SpatialEngine(idx)
    # concave "L" shape in data space
    polys = np.zeros((1, 12, 2), np.float32)
    polys[0, :6] = [[0.2, 0.2], [0.8, 0.2], [0.8, 0.5], [0.5, 0.5],
                    [0.5, 0.8], [0.2, 0.8]]
    ne = np.asarray([6], np.int32)
    got = int(eng.join_count(polys, ne)[0])
    want = int(pip_oracle(x, y, polys[0], 6).sum())
    assert got == want
