"""Streaming serve scheduler determinism (DESIGN.md §12).

The drain-on-demand test mode (``start=False``: no worker thread, the
caller pumps the SAME batch-forming code synchronously) pins:

  (a) concurrent submissions actually coalesce into micro-batches of
      width > 1 (and respect the per-spec caps derived from the
      measured wide-batch columns);
  (b) every result routes back to exactly the request that asked for
      it (distinct queries -> distinct answers);
  (c) coalesced results are BITWISE-identical to serial ``submit()``
      through the same session, for every query spec, on both kernel
      backends — batching (and the power-of-two row-0 padding that
      bounds the executable count) must never change a single bit.

Plus a real worker-thread smoke test: concurrent submitters, all
tickets resolve, results still match serial.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core import (CircleQuery, EngineConfig, Knn, PointQuery,
                        RangeCount, RangeQuery, SpatialJoin, build_index,
                        fit)
from repro.data import spatial as ds
from repro.serve import SpatialServeSession, micro_batch_caps
from repro.serve.scheduler import bench_spec_name

N = 2500


@pytest.fixture(scope="module")
def built():
    x, y = ds.make("gaussian", N, seed=3)
    part = fit("kdtree", x, y, 6, seed=0)
    return x, y, part, build_index(x, y, part)


def _warm_requests(x, y, part, qn=6, seed=0):
    rng = np.random.default_rng(seed)
    ix = rng.integers(0, len(x), qn)
    rects = ds.random_rects(qn, 1e-3, part.bounds, seed=seed + 1,
                            centers=(x, y))
    polys, ne = ds.random_polygons(4, part.bounds, seed=seed + 2)
    r = np.full(qn, 0.03, np.float32)
    return [(PointQuery(), x[ix], y[ix]),
            (RangeCount(), rects),
            (RangeQuery(), rects),
            (CircleQuery(), x[ix], y[ix], r),
            (CircleQuery(materialize=True), x[ix], y[ix], r),
            (Knn(k=5), x[ix], y[ix]),
            (SpatialJoin(), polys, ne)]


@pytest.fixture(scope="module", params=["xla", "pallas"])
def sess(request, built):
    x, y, part, index = built
    s = SpatialServeSession(
        index, config=EngineConfig(backend=request.param))
    s.warmup(_warm_requests(x, y, part))   # settle sticky + fused
    return x, y, part, s


def _assert_tree_equal(a, b, what=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), what
    for u, v in zip(la, lb):
        u, v = np.asarray(u), np.asarray(v)
        assert u.shape == v.shape and u.dtype == v.dtype, what
        assert np.array_equal(u, v), what


def _mixed_singles(x, y, part, n, seed):
    """n single-query requests over 4 spec kinds, all distinct."""
    rng = np.random.default_rng(seed)
    rects = ds.random_rects(n, 1e-3, part.bounds, seed=seed + 1,
                            centers=(x, y))
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, len(x)))
        kind = i % 4
        if kind == 0:
            reqs.append((PointQuery(), x[j:j + 1], y[j:j + 1]))
        elif kind == 1:
            reqs.append((RangeCount(), rects[i:i + 1]))
        elif kind == 2:
            reqs.append((Knn(k=5), x[j:j + 1], y[j:j + 1]))
        else:
            reqs.append((CircleQuery(), x[j:j + 1], y[j:j + 1],
                         np.full(1, 0.03, np.float32)))
    return reqs


def test_coalesce_routes_and_matches_serial(sess):
    x, y, part, s = sess
    reqs = _mixed_singles(x, y, part, 24, seed=11)
    serial = [s.submit(spec, *args) for spec, *args in reqs]
    jax.block_until_ready(serial)

    sched = s.scheduler(start=False)
    tickets = [sched.submit(spec, *args) for spec, *args in reqs]
    assert not any(t.done() for t in tickets)   # nothing ran yet
    sched.drain()
    st = sched.stats()
    # (a) concurrent submissions coalesced: 24 single-query requests
    # formed one batch per spec kind, each wider than 1
    assert st["read_batches"] == 4
    assert st["max_batch"] > 1 and st["mean_batch"] > 1
    # (b)+(c) every ticket carries ITS request's serial answer, bitwise
    for i, (t, ref) in enumerate(zip(tickets, serial)):
        assert t.done() and t.batched > 1
        _assert_tree_equal(t.result(), ref, f"request {i}")
    sched.close()


def test_bitwise_matches_serial_every_spec(sess):
    """Every spec x request widths 1..3, coalesced vs serial bitwise
    (includes the materializing range/circle windows and the join)."""
    x, y, part, s = sess
    rng = np.random.default_rng(23)
    rects = ds.random_rects(9, 1e-3, part.bounds, seed=24,
                            centers=(x, y))
    polys, ne = ds.random_polygons(6, part.bounds, seed=25)
    reqs = []
    for lo, hi in ((0, 1), (1, 3), (3, 6)):     # widths 1, 2, 3
        ix = rng.integers(0, len(x), hi - lo)
        qx, qy = x[ix], y[ix]
        r = np.full(hi - lo, 0.03, np.float32)
        reqs += [(PointQuery(), qx, qy),
                 (RangeCount(), rects[lo:hi]),
                 (RangeQuery(), rects[lo:hi]),
                 (CircleQuery(), qx, qy, r),
                 (CircleQuery(materialize=True), qx, qy, r),
                 (Knn(k=5), qx, qy),
                 (SpatialJoin(), polys[lo:hi], ne[lo:hi])]
    serial = [s.submit(spec, *args) for spec, *args in reqs]
    jax.block_until_ready(serial)

    sched = s.scheduler(start=False)
    tickets = [sched.submit(spec, *args) for spec, *args in reqs]
    sched.drain()
    st = sched.stats()
    assert st["read_batches"] == 7              # one batch per spec
    assert st["max_batch"] == 6                 # 1+2+3 coalesced
    for i, (t, ref) in enumerate(zip(tickets, serial)):
        assert t.batched == 6
        _assert_tree_equal(t.result(), ref,
                           f"request {i} ({reqs[i][0]!r})")
    sched.close()


def test_micro_batch_caps_from_bench_columns():
    cfg = EngineConfig()
    bench = {"bench_q": 16, "bench_q_wide": 256,
             "backends": {"xla": {"specs": {
                 "point": {"steady_us_per_q": 100.0,
                           "steady_us_per_q_b256": 10.0},
                 "knn10": {"steady_us_per_q": 100.0,
                           "steady_us_per_q_b256": 900.0},
                 "join": {"steady_us_per_q": 100.0}}}}}
    caps = micro_batch_caps(bench, "xla", cfg)
    # wide column cheaper -> coalesce wide; inverted -> narrow cap;
    # no wide measurement -> no cap entry (defaults to serve_max_batch)
    assert caps == {"point": 256, "knn10": 16}
    assert micro_batch_caps("/nonexistent/path.json", "xla", cfg) == {}
    assert bench_spec_name(Knn(k=10)) == "knn10"
    assert bench_spec_name(CircleQuery(materialize=True)) == "circle_mat"


def test_scheduler_honors_per_spec_cap(sess):
    x, y, part, s = sess
    bench = {"bench_q": 4, "bench_q_wide": 256,
             "specs": {"knn5": {"steady_us_per_q": 1.0,
                                "steady_us_per_q_b256": 9.0}}}
    sched = s.scheduler(bench=bench, start=False)
    assert sched.caps["knn5"] == 4
    rng = np.random.default_rng(31)
    ix = rng.integers(0, len(x), 10)
    tickets = [sched.submit(Knn(k=5), x[j:j + 1], y[j:j + 1])
               for j in ix]
    sched.drain()
    # 10 single-query kNN requests under a cap of 4 -> batches of at
    # most 4 (3 dispatches), never one 10-wide batch
    widths = [e[2] for e in sched.events if e[0] == "batch"]
    assert len(widths) == 3 and max(widths) == 4
    for t in tickets:
        assert t.done()
    sched.close()


def test_worker_thread_concurrent_submitters(sess):
    x, y, part, s = sess
    reqs = _mixed_singles(x, y, part, 32, seed=41)
    serial = [s.submit(spec, *args) for spec, *args in reqs]
    jax.block_until_ready(serial)

    with s.scheduler(start=True) as sched:
        tickets = [None] * len(reqs)

        def client(k):
            for i in range(k, len(reqs), 4):
                spec, *args = reqs[i]
                tickets[i] = sched.submit(spec, *args)

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, t in enumerate(tickets):
            _assert_tree_equal(t.result(timeout=60.0), serial[i],
                               f"request {i}")
        st = sched.stats()
        assert st["reads"] == len(reqs)
        assert st["maintain_busy"] == 0
    # closed: the scheduler rejects new work
    with pytest.raises(RuntimeError):
        sched.submit(PointQuery(), x[:1], y[:1])


def test_submit_validates_like_executor(sess):
    x, y, part, s = sess
    sched = s.scheduler(start=False)
    with pytest.raises(TypeError):
        sched.submit("point", x[:1], y[:1])
    with pytest.raises(TypeError):
        sched.submit(PointQuery(), x[:1])      # wrong arity
    sched.close()
