"""Distributed engine == single-device engine (8 fake devices).

Runs in a SUBPROCESS because XLA device count must be set before jax
initializes (conftest keeps the main test process at 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.data import spatial as ds

mesh = jax.make_mesh((8,), ("data",))
x, y = ds.make("taxi", 20000, seed=2)
part = fit("kdtree", x, y, 24)
idx = build_index(x, y, part)
single = SpatialEngine(idx)
dist = SpatialEngine(idx, mesh=mesh, part_axis="data")
dist2 = SpatialEngine(idx, mesh=jax.make_mesh((2, 4), ("pod", "data")),
                      part_axis=("pod", "data"))

rng = np.random.default_rng(0)
qx = np.concatenate([x[:16], rng.random(16).astype(np.float32)])
qy = np.concatenate([y[:16], rng.random(16).astype(np.float32)])
rects = ds.random_rects(16, 1e-3, part.bounds, seed=3, centers=(x, y))
polys, ne = ds.random_polygons(8, part.bounds, seed=5)

for eng in (dist, dist2):
    assert (np.asarray(eng.point_query(qx, qy)) ==
            np.asarray(single.point_query(qx, qy))).all()
    assert (np.asarray(eng.range_count(rects)) ==
            np.asarray(single.range_count(rects))).all()
    d2a, _ = eng.knn(qx[:8], qy[:8], 7, mode="pruned")
    d2b, _ = single.knn(qx[:8], qy[:8], 7, mode="exact")
    assert np.allclose(np.sort(np.asarray(d2a), 1),
                       np.sort(np.asarray(d2b), 1), rtol=1e-5)
    assert (np.asarray(eng.join_count(polys, ne)) ==
            np.asarray(single.join_count(polys, ne))).all()
print("DIST-OK")
"""


@pytest.mark.slow
def test_distributed_engine_matches_single():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DIST-OK" in out.stdout, out.stdout + out.stderr
