#!/usr/bin/env bash
# Repo check: tier-1 tests + quick perf smoke (BENCH_quick.json).
#
#   bash tools/check.sh
#
# The quick benchmark exercises every QuerySpec through the unified
# executor at tiny sizes and writes BENCH_quick.json so perf trajectory
# can be diffed across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
# deselected: known-failing at seed (test_hlo_walk TypeError, moe aux
# loss tolerance) or timing-flaky on loaded runners (build scaling) —
# tracked in ROADMAP.md Open items
python -m pytest -q \
  --deselect tests/test_hlo_walk.py::test_scan_trip_count_multiplies_flops \
  --deselect tests/test_moe.py::test_aux_loss_uniformity \
  --deselect tests/test_system.py::test_build_scales_subquadratically

echo "== quick benchmark smoke =="
python -m benchmarks.run --quick

echo "== BENCH_quick.json summary =="
python - <<'EOF'
import json
rep = json.load(open("BENCH_quick.json"))
bad = [n for n, s in rep["specs"].items() if s["steady_host_syncs"] > 0]
for name, s in sorted(rep["specs"].items()):
    print(f"  {name:12s} cold {s['cold_us_per_q']:9.1f} us/q   "
          f"steady {s['steady_us_per_q']:9.1f} us/q   "
          f"syncs {s['steady_host_syncs']}")
assert not bad, f"steady-state host syncs detected: {bad}"
print("OK: all specs zero-sync in steady state")
EOF
