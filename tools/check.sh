#!/usr/bin/env bash
# Repo check: tier-1 tests + quick perf smoke (BENCH_quick.json).
#
#   bash tools/check.sh
#
# The quick benchmark exercises every QuerySpec through the unified
# executor on BOTH kernel backends (xla + pallas-interpret) at tiny
# sizes and writes BENCH_quick.json so perf trajectory can be diffed
# across PRs; a >25% steady-state regression of EITHER backend vs the
# committed BENCH_quick.json fails the check, with a per-spec delta
# table naming the offender (override the budget with
# BENCH_REGRESSION_PCT, or skip with SKIP_BENCH_DIFF=1 on runners
# whose speed is incomparable to the committed baseline's).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
# (includes the kernel-backend parity suite, tests/test_backends.py,
# and the query-axis sharding check, tests/test_query_shard.py)
python -m pytest -q

echo "== quick benchmark smoke =="
BASELINE=""
if git cat-file -e HEAD:BENCH_quick.json 2>/dev/null; then
  BASELINE="$(mktemp)"
  git show HEAD:BENCH_quick.json > "$BASELINE"
fi
python -m benchmarks.run --quick

echo "== BENCH_quick.json summary =="
BENCH_BASELINE="$BASELINE" python - <<'EOF'
import json, os
rep = json.load(open("BENCH_quick.json"))
bad = []
for backend, br in sorted(rep["backends"].items()):
    for n, s in br["specs"].items():
        if s["steady_host_syncs"] > 0:
            bad.append(f"{backend}/{n}")
for backend, br in sorted(rep["backends"].items()):
    print(f"  [{backend}]")
    for name, s in sorted(br["specs"].items()):
        wide = s.get("steady_us_per_q_b256")
        wide_s = f"   q256 {wide:9.1f} us/q" if wide is not None else ""
        print(f"  {name:12s} cold {s['cold_us_per_q']:9.1f} us/q   "
              f"steady {s['steady_us_per_q']:9.1f} us/q   "
              f"syncs {s['steady_host_syncs']}{wide_s}")
    u = br.get("updates")
    if u:
        print(f"  {'updates':12s} insert {u['insert_us_per_op']:7.1f} "
              f"us/op ({u['inserts_per_s']}/s)   refit "
              f"{u['refit_ms']:.1f} ms/{u['refit_partitions']}p   "
              f"post range {u['post_range_us_per_q']:.1f} us/q   "
              f"post circle {u['post_circle_us_per_q']:.1f} us/q")
    sv = br.get("serve")
    if sv:
        print(f"  {'serve':12s} sched {sv['qps']:9.1f} q/s vs serial "
              f"{sv['serial_qps']:9.1f} q/s (x{sv['coalesce_speedup']})"
              f"   mean batch {sv['mean_batch']}")
        print(f"  {'':12s} mixed p50 {sv['p50_us']:9.1f} us  p99 "
              f"{sv['p99_us']:9.1f} us   ingest "
              f"{sv['ingest_ops_per_s']:.0f} ops/s   maintain "
              f"{sv['maintain_runs']} runs ({sv['maintain_busy']} busy)")
assert not bad, f"steady-state host syncs detected: {bad}"
print("OK: all specs zero-sync in steady state (every backend)")

# -- serve scheduler invariants: deterministic, so gated ALWAYS ------
# (timing-free: coalescing must never change a bit, and maintain()
# must only ever have run against an empty queue)
for backend, br in sorted(rep["backends"].items()):
    sv = br.get("serve")
    if not sv:
        continue
    assert sv["bitwise_vs_serial"], (
        f"{backend}: scheduler-coalesced results diverged from serial "
        "submit() — batching must be bitwise-neutral")
    assert sv["maintain_busy"] == 0, (
        f"{backend}: maintain() ran {sv['maintain_busy']}x with a "
        "non-empty queue — maintenance must stay off the hot path")
print("OK: serve scheduler bitwise-neutral, maintenance idle-only")

# -- perf-trajectory gate: BOTH backends' steady us/q vs committed --
# (per-spec delta table so a regression names the backend AND spec)
base_path = os.environ.get("BENCH_BASELINE") or ""
if os.environ.get("SKIP_BENCH_DIFF") == "1" or not base_path:
    print("perf gate: skipped (no committed baseline)")
    raise SystemExit(0)
budget = float(os.environ.get("BENCH_REGRESSION_PCT", "25"))
base = json.load(open(base_path))
base_backends = base.get("backends") or {"_default": base}
regressions = []
for backend, br in sorted(rep["backends"].items()):
    bb = base_backends.get(backend)
    if bb is None and backend == rep.get("backend_default"):
        bb = base_backends.get("_default")   # pre-backends baseline
    if bb is None:
        continue
    print(f"  gate [{backend}]")
    for name, s in sorted(br["specs"].items()):
        b = bb.get("specs", {}).get(name)
        if not b:
            continue
        for key, label in (("steady_us_per_q", "q16 "),
                           ("steady_us_per_q_b256", "q256")):
            if key not in b or key not in s:
                continue
            old, new = b[key], s[key]
            pct = (new - old) / max(old, 1e-9) * 100
            flag = " <-- REGRESSION" if pct > budget else ""
            print(f"    {name:12s} {label} {old:9.1f} -> {new:9.1f} "
                  f"us/q ({pct:+6.1f}%){flag}")
            if pct > budget:
                regressions.append((backend, name, label.strip(), old,
                                    new, round(pct, 1)))
    # update-throughput columns ride the same regression table
    u, bu = br.get("updates"), bb.get("updates")
    for key in ("insert_us_per_op", "post_range_us_per_q",
                "post_circle_us_per_q"):
        if not (u and bu) or key not in u or key not in bu:
            continue
        old, new = bu[key], u[key]
        pct = (new - old) / max(old, 1e-9) * 100
        flag = " <-- REGRESSION" if pct > budget else ""
        print(f"    {'updates':12s} {key:20s} {old:9.1f} -> "
              f"{new:9.1f} ({pct:+6.1f}%){flag}")
        if pct > budget:
            regressions.append((backend, "updates", key, old, new,
                                round(pct, 1)))
    # serve-scheduler columns: p50 latency (higher = worse) and
    # coalesced qps (lower = worse, so the delta sign is inverted)
    sv, bsv = br.get("serve"), bb.get("serve")
    if sv and bsv:
        for key, invert in (("p50_us", False), ("qps", True)):
            if key not in sv or key not in bsv:
                continue
            old, new = bsv[key], sv[key]
            pct = (old - new if invert else new - old) \
                / max(old, 1e-9) * 100
            flag = " <-- REGRESSION" if pct > budget else ""
            print(f"    {'serve':12s} {key:20s} {old:9.1f} -> "
                  f"{new:9.1f} ({pct:+6.1f}%){flag}")
            if pct > budget:
                regressions.append((backend, "serve", key, old, new,
                                    round(pct, 1)))
        # the acceptance bar: coalescing must not LOSE throughput
        if sv["coalesce_speedup"] < 1.0:
            regressions.append((backend, "serve", "coalesce_speedup",
                                1.0, sv["coalesce_speedup"], 0.0))
assert not regressions, (
    f"steady-state us/q regressed >{budget}% vs committed "
    f"BENCH_quick.json: {regressions}")
print(f"OK: no spec on any backend regressed more than {budget}% "
      "vs committed baseline")
EOF
